//! Concurrent multi-tenant planning service: the `&self`-shareable engine
//! behind [`crate::coordinator::service::PlannerService`] and the serving
//! runtime (DESIGN.md §8).
//!
//! The single-threaded service serializes every tenant behind one `&mut
//! self`; a production planner serves thousands of concurrent
//! heterogeneous [`PlanRequest`]s. [`ConcurrentService`] takes planning to
//! `&self` with four mechanisms, all on `std::sync` (the build stays
//! dependency-free):
//!
//! * **Fingerprint-sharded LRU.** Contexts are keyed by
//!   [`fingerprint_req`] and spread over N shards (`shard = fp % N`), each
//!   an independently locked LRU of `Arc<ProblemCtx>`. The shard lock is
//!   held only for the map operation — never across context construction
//!   or solving — so a cache hit is a position scan + `Arc` clone, and
//!   tenants on different shards never contend at all. The handed-out
//!   `Arc<ProblemCtx>` is itself `Sync`: its `OnceLock` artifact cells
//!   give per-artifact single-flight *within* a context for free.
//! * **Single-flight context construction.** Two concurrent requests with
//!   the same fingerprint build the `ProblemCtx` once: the first becomes
//!   the builder and registers an in-flight entry; later arrivals block on
//!   its condvar and receive the builder's `Arc` — they never clone the
//!   graph or recompute anything ([`ConcurrentService::dedup_waits`]
//!   counts them). The builder publishes into the LRU *before* notifying,
//!   so a waiter's wake always finds the value. The published value is a
//!   `Result`: if the build panics, the builder publishes the *error* and
//!   every deduped waiter wakes with it — no request ever hangs on a dead
//!   builder (DESIGN.md §11).
//! * **Budget-keyed incumbent cache.** IP solves store their final
//!   incumbent ([`WarmSeed`]) under `(fingerprint, warm_seed_key)` with
//!   the budget that produced it; a repeat solve of the same problem and
//!   regime resumes from it instead of restarting — a longer-budget
//!   re-solve continues where the short one stopped. Seeding is monotone
//!   (engines take a seed only when strictly better than their own warm
//!   start, and only improve it), so a warm-started solve never returns a
//!   worse objective than a cold one. Seeds are only kept for
//!   LRU-resident fingerprints and are dropped on eviction and
//!   [`ConcurrentService::clear`], so the cache is bounded by
//!   `capacity × |keys|` and can never serve a stale problem.
//! * **Fault containment and admission control.** Context builds and
//!   solves run under an unwind envelope: a panic fails that one request
//!   with [`PlaceError::SolverPanicked`] and leaves the service fully
//!   operational. Shard locks recover from poisoning by evicting the
//!   (rebuildable) cached state instead of propagating the panic. An
//!   optional admission controller ([`ConcurrentService::with_admission`])
//!   bounds concurrent solves with a bounded wait queue and a per-tenant
//!   in-flight cap, shedding excess load as [`PlaceError::Overloaded`]
//!   instead of letting queues grow without bound.

use crate::algos::PlaceError;
use crate::coordinator::context::{
    fingerprint_req, PlanResult, ProblemCtx, SolveOpts, Solver, WarmSeed,
};
use crate::coordinator::placement::{PlanRequest, Scenario};
use crate::coordinator::planner::{self, Algorithm};
use crate::graph::OpGraph;
use crate::obs;
use crate::workloads::Workload;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Where a fault-injection hook fires (chaos/test instrumentation): just
/// before a context build or a solve, inside the service's unwind
/// envelope. A hook that panics exercises exactly the recovery paths a
/// buggy solver would.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// About to build a `ProblemCtx` (outside the shard lock).
    ContextBuild,
    /// About to run a solve for the given fingerprint.
    Solve,
}

/// A process-wide fault-injection hook: `(point, fingerprint)`.
pub type FaultHook = Arc<dyn Fn(FaultPoint, u64) + Send + Sync>;

static FAULT_ARMED: AtomicBool = AtomicBool::new(false);
static FAULT_HOOK: Mutex<Option<FaultHook>> = Mutex::new(None);

/// Install (or with `None`, remove) the process-wide fault-injection
/// hook. Test-only in spirit; when no hook is armed the per-solve cost is
/// one relaxed atomic load.
pub fn set_fault_hook(hook: Option<FaultHook>) {
    let mut slot = FAULT_HOOK.lock().unwrap_or_else(|p| p.into_inner());
    FAULT_ARMED.store(hook.is_some(), Ordering::Relaxed);
    *slot = hook;
}

fn fire_fault(point: FaultPoint, fp: u64) {
    if !FAULT_ARMED.load(Ordering::Relaxed) {
        return;
    }
    let hook = FAULT_HOOK.lock().unwrap_or_else(|p| p.into_inner()).clone();
    if let Some(h) = hook {
        h(point, fp);
    }
}

/// One shard's state: an LRU of contexts, the in-flight build registry,
/// and the incumbent seeds of the resident fingerprints.
struct Shard {
    /// Most-recently-used last.
    entries: VecDeque<(u64, Arc<ProblemCtx>)>,
    /// Fingerprints with a context build in flight (tiny: at most the
    /// number of concurrently building tenants on this shard).
    inflight: Vec<(u64, Arc<InFlight>)>,
    /// Budget-keyed incumbent seeds, keyed `(fingerprint,
    /// warm_seed_key)`. Invariant: every fingerprint here is resident in
    /// `entries` (eviction and `clear` drop its seeds with it).
    incumbents: Vec<((u64, u8), SeedEntry)>,
}

/// Lock a shard, recovering from poisoning: the cached entries and seeds
/// are rebuildable derived state, so a panic that poisoned the lock costs
/// us the shard's cache — never the service. In-flight registrations are
/// kept (their builders publish a `Result` through their own unwind
/// envelope, so waiters still wake).
fn lock_shard(m: &Mutex<Shard>) -> MutexGuard<'_, Shard> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => {
            m.clear_poison();
            let mut guard = poisoned.into_inner();
            guard.entries.clear();
            guard.incumbents.clear();
            obs::counter("plan_shard_poison_recoveries_total").inc();
            guard
        }
    }
}

/// A context build in progress: waiters block on the condvar until the
/// builder publishes the finished `Arc` — or, if the build panicked, the
/// error. Publishing a `Result` (not a bare `Arc`) is what guarantees the
/// "waiters always wake" invariant (DESIGN.md §11): every exit path of
/// the builder, including unwinds, completes the flight.
struct InFlight {
    done: Mutex<Option<Result<Arc<ProblemCtx>, PlaceError>>>,
    cv: Condvar,
}

impl InFlight {
    fn new() -> InFlight {
        InFlight { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn wait(&self) -> Result<Arc<ProblemCtx>, PlaceError> {
        let mut done = self.done.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(result) = done.as_ref() {
                return result.clone();
            }
            done = self.cv.wait(done).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn publish(&self, result: Result<Arc<ProblemCtx>, PlaceError>) {
        *self.done.lock().unwrap_or_else(|p| p.into_inner()) = Some(result);
        self.cv.notify_all();
    }
}

/// One cached incumbent: the seed, its objective in its own search space,
/// and the solve budget that produced it.
struct SeedEntry {
    seed: WarmSeed,
    objective: f64,
    budget: Duration,
}

/// One shard's registered obs series (DESIGN.md §10): hit/miss/dedup
/// counters plus a plan-latency histogram, labeled `{shard="i"}` so the
/// Prometheus export shows where tenants contend. Handles are resolved
/// once at construction; bumping them is a relaxed atomic op. Instances
/// sharing a shard index share the series — the registry aggregates
/// process-wide.
struct ShardObs {
    hits: Arc<obs::Counter>,
    misses: Arc<obs::Counter>,
    dedup: Arc<obs::Counter>,
    latency_ms: Arc<obs::AtomicHistogram>,
}

impl ShardObs {
    fn new(i: usize) -> ShardObs {
        ShardObs {
            hits: obs::counter(&format!("plan_shard_hits_total{{shard=\"{i}\"}}")),
            misses: obs::counter(&format!("plan_shard_misses_total{{shard=\"{i}\"}}")),
            dedup: obs::counter(&format!("plan_shard_dedup_waits_total{{shard=\"{i}\"}}")),
            latency_ms: obs::histogram(&format!("plan_latency_ms{{shard=\"{i}\"}}")),
        }
    }
}

/// Admission-controller limits: a hard cap on concurrent solves, a
/// bounded FIFO wait queue behind it, and an optional per-tenant
/// (per-fingerprint) in-flight cap so one hot tenant cannot monopolize
/// the solve slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmissionLimits {
    /// Solves allowed to run at once (clamped to ≥ 1).
    pub max_concurrent: usize,
    /// Requests allowed to wait for a slot before shedding starts.
    pub max_queue: usize,
    /// Max in-flight requests per fingerprint class (0 = unlimited).
    pub per_tenant: usize,
}

/// The admission controller: a counting semaphore with a bounded wait
/// queue and per-tenant fairness, on `Mutex` + `Condvar`. Requests past
/// both bounds are shed with [`PlaceError::Overloaded`] — the queue can
/// never grow without bound, and a queued request whose deadline passes
/// gives up (sheds) rather than solving uselessly late.
struct Admission {
    limits: AdmissionLimits,
    state: Mutex<AdmState>,
    cv: Condvar,
    shed: AtomicUsize,
    queue_waits: AtomicUsize,
    shed_obs: Arc<obs::Counter>,
    queue_obs: Arc<obs::Counter>,
}

struct AdmState {
    active: usize,
    queued: usize,
    /// In-flight count per fingerprint class (tiny: at most
    /// `max_concurrent + max_queue` distinct entries).
    per_fp: Vec<(u64, usize)>,
}

impl Admission {
    fn new(limits: AdmissionLimits) -> Admission {
        Admission {
            limits,
            state: Mutex::new(AdmState { active: 0, queued: 0, per_fp: Vec::new() }),
            cv: Condvar::new(),
            shed: AtomicUsize::new(0),
            queue_waits: AtomicUsize::new(0),
            shed_obs: obs::counter("plan_admission_shed_total"),
            queue_obs: obs::counter("plan_admission_queue_waits_total"),
        }
    }

    fn shed_one(&self) -> PlaceError {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.shed_obs.inc();
        PlaceError::Overloaded
    }

    /// Acquire a solve slot for `fp`, waiting (bounded) if the service is
    /// at its concurrency limit. The returned permit releases the slot on
    /// drop — including when the solve panics, so admission accounting
    /// survives unwinds.
    fn acquire(
        &self,
        fp: u64,
        deadline: Option<Instant>,
    ) -> Result<AdmissionPermit<'_>, PlaceError> {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        // per-tenant fairness: a class at its in-flight cap is shed
        // immediately — it never consumes queue slots other tenants need
        if self.limits.per_tenant > 0 {
            let n = st.per_fp.iter().find(|(f, _)| *f == fp).map_or(0, |(_, n)| *n);
            if n >= self.limits.per_tenant {
                return Err(self.shed_one());
            }
        }
        if st.active >= self.limits.max_concurrent {
            if st.queued >= self.limits.max_queue {
                return Err(self.shed_one());
            }
            st.queued += 1;
            self.queue_waits.fetch_add(1, Ordering::Relaxed);
            self.queue_obs.inc();
            while st.active >= self.limits.max_concurrent {
                match deadline {
                    None => st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner()),
                    Some(d) => {
                        let left = d.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            // deadline passed while queued: solving now
                            // would only produce a uselessly late answer
                            st.queued -= 1;
                            return Err(self.shed_one());
                        }
                        let (guard, _timed_out) =
                            self.cv.wait_timeout(st, left).unwrap_or_else(|p| p.into_inner());
                        st = guard;
                    }
                }
            }
            st.queued -= 1;
        }
        st.active += 1;
        if self.limits.per_tenant > 0 {
            match st.per_fp.iter_mut().find(|(f, _)| *f == fp) {
                Some((_, n)) => *n += 1,
                None => st.per_fp.push((fp, 1)),
            }
        }
        Ok(AdmissionPermit { adm: self, fp })
    }

    fn release(&self, fp: u64) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.active = st.active.saturating_sub(1);
        if self.limits.per_tenant > 0 {
            if let Some(pos) = st.per_fp.iter().position(|(f, _)| *f == fp) {
                st.per_fp[pos].1 -= 1;
                if st.per_fp[pos].1 == 0 {
                    st.per_fp.swap_remove(pos);
                }
            }
        }
        drop(st);
        self.cv.notify_one();
    }
}

/// RAII solve slot: releasing on drop keeps the admission counters exact
/// even when a solve unwinds.
struct AdmissionPermit<'a> {
    adm: &'a Admission,
    fp: u64,
}

impl Drop for AdmissionPermit<'_> {
    fn drop(&mut self) {
        self.adm.release(self.fp);
    }
}

/// Concurrent, shareable planning service — see the module docs. All
/// planning entry points take `&self`; wrap one in an `Arc` and hand
/// clones to worker threads (or borrow it across a
/// [`std::thread::scope`]).
pub struct ConcurrentService {
    shards: Vec<Mutex<Shard>>,
    /// Parallel to `shards`: the registered per-shard obs series.
    shard_obs: Vec<ShardObs>,
    /// Per-shard LRU capacity (total capacity ÷ shard count, rounded up).
    shard_capacity: usize,
    /// Lattice enumeration cap for the contexts this service creates.
    ideal_cap: usize,
    /// Optional admission controller (`None` = admit everything, the
    /// historical behavior).
    admission: Option<Admission>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    dedup_waits: AtomicUsize,
}

impl ConcurrentService {
    /// Service over `shards` fingerprint shards caching up to `capacity`
    /// contexts in total (both clamped to ≥ 1), with the default lattice
    /// cap.
    pub fn new(shards: usize, capacity: usize) -> ConcurrentService {
        Self::with_ideal_cap(shards, capacity, crate::graph::ideals::DEFAULT_IDEAL_CAP)
    }

    /// [`ConcurrentService::new`] with an explicit lattice cap for the
    /// contexts it creates (see
    /// [`crate::coordinator::service::PlannerService::with_ideal_cap`]).
    pub fn with_ideal_cap(
        shards: usize,
        capacity: usize,
        ideal_cap: usize,
    ) -> ConcurrentService {
        let shards = shards.max(1);
        ConcurrentService {
            shard_capacity: capacity.max(1).div_ceil(shards),
            shard_obs: (0..shards).map(ShardObs::new).collect(),
            shards: (0..shards)
                .map(|_| {
                    Mutex::new(Shard {
                        entries: VecDeque::new(),
                        inflight: Vec::new(),
                        incumbents: Vec::new(),
                    })
                })
                .collect(),
            ideal_cap,
            admission: None,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            dedup_waits: AtomicUsize::new(0),
        }
    }

    /// Enable admission control with the given limits. Requests beyond
    /// `max_concurrent` running + `max_queue` waiting — or beyond the
    /// per-tenant in-flight cap — are shed with
    /// [`PlaceError::Overloaded`].
    pub fn with_admission(mut self, limits: AdmissionLimits) -> ConcurrentService {
        let limits =
            AdmissionLimits { max_concurrent: limits.max_concurrent.max(1), ..limits };
        self.admission = Some(Admission::new(limits));
        self
    }

    /// The configured admission limits, if admission control is on.
    pub fn admission_limits(&self) -> Option<AdmissionLimits> {
        self.admission.as_ref().map(|a| a.limits)
    }

    /// Requests shed by the admission controller so far (0 when off).
    pub fn shed(&self) -> usize {
        self.admission.as_ref().map_or(0, |a| a.shed.load(Ordering::Relaxed))
    }

    /// Requests that waited in the admission queue so far (0 when off).
    pub fn queue_waits(&self) -> usize {
        self.admission.as_ref().map_or(0, |a| a.queue_waits.load(Ordering::Relaxed))
    }

    fn admit(
        &self,
        fp: u64,
        opts: &SolveOpts,
    ) -> Result<Option<AdmissionPermit<'_>>, PlaceError> {
        match &self.admission {
            None => Ok(None),
            Some(a) => a.acquire(fp, opts.budget.deadline).map(Some),
        }
    }

    fn shard_index(&self, fp: u64) -> usize {
        (fp % self.shards.len() as u64) as usize
    }

    fn shard(&self, fp: u64) -> &Mutex<Shard> {
        &self.shards[self.shard_index(fp)]
    }

    /// The context for `(graph, scenario)` — the scalar adapter entry.
    pub fn context(&self, g: &OpGraph, sc: &Scenario) -> Result<Arc<ProblemCtx>, PlaceError> {
        self.context_request(g, &sc.to_request())
    }

    /// The context for `(graph, request)`: cached if its fingerprint is
    /// resident, adopted from a concurrent builder if one is in flight,
    /// freshly built (once, and cached) otherwise. Requests differing only
    /// in solver selectors (objective / contiguity / algorithm) share one
    /// context ([`fingerprint_req`] excludes them).
    ///
    /// A build that panics fails with [`PlaceError::SolverPanicked`] — for
    /// the builder *and* every deduped waiter, which wake with the same
    /// error instead of hanging. The fingerprint is not cached, so the
    /// next request retries the build.
    pub fn context_request(
        &self,
        g: &OpGraph,
        req: &PlanRequest,
    ) -> Result<Arc<ProblemCtx>, PlaceError> {
        let fp = fingerprint_req(g, req);
        let sobs = &self.shard_obs[self.shard_index(fp)];
        let shard = self.shard(fp);
        let flight = {
            let mut s = lock_shard(shard);
            if let Some(pos) = s.entries.iter().position(|(key, _)| *key == fp) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                sobs.hits.inc();
                let entry = s.entries.remove(pos).expect("position just found");
                s.entries.push_back(entry.clone());
                return Ok(entry.1);
            }
            if let Some(f) = s.inflight.iter().find(|(key, _)| *key == fp) {
                // another tenant is building this exact context right now:
                // wait for its Arc instead of recomputing (single-flight)
                let f = Arc::clone(&f.1);
                drop(s);
                self.dedup_waits.fetch_add(1, Ordering::Relaxed);
                sobs.dedup.inc();
                return f.wait();
            }
            // we are the builder: register before releasing the lock
            self.misses.fetch_add(1, Ordering::Relaxed);
            sobs.misses.inc();
            let f = Arc::new(InFlight::new());
            s.inflight.push((fp, Arc::clone(&f)));
            f
        };
        // build OUTSIDE the shard lock — hits and other builds proceed —
        // and inside an unwind envelope: every exit path below, panic
        // included, deregisters the flight and publishes a Result
        let built = catch_unwind(AssertUnwindSafe(|| {
            fire_fault(FaultPoint::ContextBuild, fp);
            Arc::new(ProblemCtx::from_request_with_cap(g.clone(), req.clone(), self.ideal_cap))
        }));
        match built {
            Ok(ctx) => {
                {
                    let mut s = lock_shard(shard);
                    s.inflight.retain(|(key, _)| *key != fp);
                    s.entries.push_back((fp, Arc::clone(&ctx)));
                    while s.entries.len() > self.shard_capacity {
                        if let Some((evicted, _)) = s.entries.pop_front() {
                            // satellite invariant: evicting a context drops its
                            // incumbent seeds — the cache stays bounded and can
                            // never seed a fingerprint it no longer holds
                            s.incumbents.retain(|((key, _), _)| *key != evicted);
                        }
                    }
                }
                flight.publish(Ok(Arc::clone(&ctx)));
                Ok(ctx)
            }
            Err(payload) => {
                let err = PlaceError::SolverPanicked(format!(
                    "context build: {}",
                    planner::panic_message(&payload)
                ));
                {
                    let mut s = lock_shard(shard);
                    s.inflight.retain(|(key, _)| *key != fp);
                }
                obs::counter("plan_ctx_build_panics_total").inc();
                flight.publish(Err(err.clone()));
                Err(err)
            }
        }
    }

    /// The cached incumbent seed for `(fingerprint, key)`, if any.
    fn lookup_seed(&self, fp: u64, key: u8) -> Option<WarmSeed> {
        let s = lock_shard(self.shard(fp));
        s.incumbents
            .iter()
            .find(|((f, k), _)| *f == fp && *k == key)
            .map(|(_, e)| e.seed.clone())
    }

    /// Store a solve's final incumbent under `(fingerprint, key)`, keeping
    /// the best objective seen (ties broken toward the longer budget — a
    /// longer-budget re-solve has explored strictly more of the tree, so
    /// its equal-objective incumbent carries the stronger proof state).
    /// Dropped silently when the fingerprint is no longer LRU-resident.
    fn store_seed(&self, fp: u64, key: u8, seed: &WarmSeed, budget: Duration) {
        let mut s = lock_shard(self.shard(fp));
        if !s.entries.iter().any(|(f, _)| *f == fp) {
            return; // evicted while we were solving: do not resurrect
        }
        let objective = seed.objective();
        match s.incumbents.iter_mut().find(|((f, k), _)| *f == fp && *k == key) {
            Some((_, e)) => {
                let better = objective < e.objective - 1e-12;
                let longer_tie = objective <= e.objective + 1e-12 && budget > e.budget;
                if better || longer_tie {
                    *e = SeedEntry { seed: seed.clone(), objective, budget };
                }
            }
            None => {
                s.incumbents.push(((fp, key), SeedEntry { seed: seed.clone(), objective, budget }));
            }
        }
    }

    /// Plan `(graph, scenario)` with `alg`, reusing every cached artifact.
    /// Seed-free (exactly the sequential service's historical behavior);
    /// the incumbent cache rides [`ConcurrentService::plan_request`].
    pub fn plan(
        &self,
        g: &OpGraph,
        sc: &Scenario,
        alg: Algorithm,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        let req = sc.to_request();
        let fp = fingerprint_req(g, &req);
        let _permit = self.admit(fp, opts)?;
        let ctx = self.context_request(g, &req)?;
        match catch_unwind(AssertUnwindSafe(|| {
            fire_fault(FaultPoint::Solve, fp);
            alg.solver().solve(&ctx, opts)
        })) {
            Ok(result) => result,
            Err(payload) => {
                obs::counter("plan_solver_panics_total").inc();
                Err(PlaceError::SolverPanicked(planner::panic_message(&payload)))
            }
        }
    }

    /// Plan a [`PlanRequest`] (fleet + objective + algorithm selection,
    /// `Auto` included), reusing every cached artifact *and* the incumbent
    /// cache: when the request resolves to an IP engine
    /// ([`planner::warm_seed_key`]), the solve resumes from the best prior
    /// incumbent of the same `(problem, regime)` and its own final
    /// incumbent is stored back for the next tenant.
    ///
    /// Resilience: the request is first admitted (when admission control
    /// is on — [`PlaceError::Overloaded`] on shed), and the whole solve
    /// runs under an unwind envelope, so a panicking solver fails *this*
    /// request with [`PlaceError::SolverPanicked`] and nothing else.
    pub fn plan_request(
        &self,
        g: &OpGraph,
        req: &PlanRequest,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        let _span = obs::span_cat("plan_request", "planner");
        let started = Instant::now();
        let fp = fingerprint_req(g, req);
        let _permit = self.admit(fp, opts)?;
        let ctx = self.context_request(g, req)?;
        let key = planner::warm_seed_key(req);
        let solved = catch_unwind(AssertUnwindSafe(|| {
            fire_fault(FaultPoint::Solve, fp);
            match key {
                None => planner::solve_request(&ctx, req, opts),
                Some(k) => {
                    let mut seeded = opts.clone();
                    seeded.warm_seed = self.lookup_seed(ctx.fingerprint(), k);
                    let result = planner::solve_request(&ctx, req, &seeded)?;
                    if let Some(seed) = &result.warm_seed {
                        self.store_seed(ctx.fingerprint(), k, seed, seeded.ip_budget);
                    }
                    Ok(result)
                }
            }
        }));
        let result = match solved {
            Ok(result) => result?,
            Err(payload) => {
                obs::counter("plan_solver_panics_total").inc();
                return Err(PlaceError::SolverPanicked(planner::panic_message(&payload)));
            }
        };
        let sobs = &self.shard_obs[self.shard_index(ctx.fingerprint())];
        sobs.latency_ms.observe(started.elapsed().as_secs_f64() * 1e3);
        Ok(result)
    }

    /// [`ConcurrentService::plan`] for a [`Workload`], filling the expert
    /// rule from the workload when the caller didn't set one.
    pub fn plan_workload(
        &self,
        w: &Workload,
        alg: Algorithm,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        let mut opts = opts.clone();
        if opts.expert.is_none() {
            opts.expert = w.expert;
        }
        self.plan(&w.graph, &w.scenario, alg, &opts)
    }

    /// Cache hits so far (across all shards).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses so far (= contexts built by this service).
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Requests that adopted a concurrent builder's context instead of
    /// building their own (the single-flight dedup counter).
    pub fn dedup_waits(&self) -> usize {
        self.dedup_waits.load(Ordering::Relaxed)
    }

    /// Cached contexts currently held, across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_shard(s).entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Incumbent seeds currently cached, across all shards.
    pub fn seeds_len(&self) -> usize {
        self.shards.iter().map(|s| lock_shard(s).incumbents.len()).sum()
    }

    /// Drop every cached context AND every incumbent seed (e.g. after an
    /// external cost-model update that invalidates everything). In-flight
    /// builds are not interrupted; they re-insert their (fresh) context on
    /// completion.
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut s = lock_shard(shard);
            s.entries.clear();
            s.incumbents.clear();
        }
    }
}

impl Default for ConcurrentService {
    /// Eight shards × eight contexts each — a serving-sized default.
    fn default() -> ConcurrentService {
        ConcurrentService::new(8, 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::{AlgoChoice, Objective};
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).mem(1.0).comm(0.2));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn shared_reference_planning_hits_cache() {
        let g = chain(6);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let svc = ConcurrentService::new(4, 8);
        let a = svc.context(&g, &sc).unwrap();
        let b = svc.context(&g, &sc).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(svc.hits(), 1);
        assert_eq!(svc.misses(), 1);
        assert_eq!(svc.dedup_waits(), 0);
    }

    #[test]
    fn concurrent_same_fingerprint_builds_once() {
        let g = chain(6);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let svc = ConcurrentService::new(4, 8);
        let ctxs: Vec<Arc<ProblemCtx>> = std::thread::scope(|scope| {
            let handles: Vec<_> =
                (0..8).map(|_| scope.spawn(|| svc.context(&g, &sc).unwrap())).collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        for c in &ctxs[1..] {
            assert!(Arc::ptr_eq(&ctxs[0], c), "all threads must share one context");
        }
        assert_eq!(svc.misses(), 1, "single-flight: exactly one build");
        assert_eq!(
            svc.hits() + svc.dedup_waits() + svc.misses(),
            8,
            "every request is a hit, a dedup wait, or the one build"
        );
    }

    #[test]
    fn eviction_drops_incumbent_seeds() {
        let g = chain(6);
        // capacity 2, one shard, so a third fingerprint evicts the first
        let svc = ConcurrentService::new(1, 2);
        let opts = SolveOpts { ip_budget: Duration::from_secs(2), ..SolveOpts::default() };
        let req = |k| {
            PlanRequest::new(crate::coordinator::placement::Fleet::uniform(
                k,
                1,
                f64::INFINITY,
            ))
            .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous))
        };
        svc.plan_request(&g, &req(2), &opts).unwrap();
        assert_eq!(svc.seeds_len(), 1, "IP solve must store its incumbent");
        svc.plan_request(&g, &req(3), &opts).unwrap();
        svc.plan_request(&g, &req(4), &opts).unwrap();
        assert_eq!(svc.len(), 2, "capacity bound");
        assert_eq!(svc.seeds_len(), 2, "evicted fingerprint's seed must go with it");
        svc.clear();
        assert!(svc.is_empty());
        assert_eq!(svc.seeds_len(), 0, "clear drops seeds too");
    }

    #[test]
    fn warm_seeded_resolve_is_never_worse_and_identical_when_closed() {
        let g = chain(8);
        let svc = ConcurrentService::new(2, 8);
        // gap 0 ⇒ the IP closes this small instance to proven optimality,
        // making the warm-started re-solve provably identical to the cold
        let opts = SolveOpts {
            ip_budget: Duration::from_secs(10),
            gap_target: 0.0,
            ..SolveOpts::default()
        };
        let req = PlanRequest::new(crate::coordinator::placement::Fleet::uniform(
            2,
            1,
            f64::INFINITY,
        ))
        .objective(Objective::Throughput)
        .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous));
        let cold = svc.plan_request(&g, &req, &opts).unwrap();
        let warm = svc.plan_request(&g, &req, &opts).unwrap();
        assert_eq!(cold.placement.assignment, warm.placement.assignment);
        assert_eq!(
            cold.placement.objective.to_bits(),
            warm.placement.objective.to_bits(),
            "seeded re-solve of a closed instance must be bitwise identical"
        );
    }

    #[test]
    fn longer_budget_resolve_updates_the_stored_seed() {
        let g = chain(6);
        let svc = ConcurrentService::new(1, 4);
        let req = PlanRequest::new(crate::coordinator::placement::Fleet::uniform(
            2,
            1,
            f64::INFINITY,
        ))
        .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous));
        let short = SolveOpts { ip_budget: Duration::from_millis(50), ..SolveOpts::default() };
        let long = SolveOpts { ip_budget: Duration::from_secs(5), ..SolveOpts::default() };
        svc.plan_request(&g, &req, &short).unwrap();
        let fp = fingerprint_req(&g, &req);
        let stored_short = {
            let s = svc.shard(fp).lock().unwrap();
            s.incumbents[0].1.budget
        };
        assert_eq!(stored_short, short.ip_budget);
        svc.plan_request(&g, &req, &long).unwrap();
        let stored_long = {
            let s = svc.shard(fp).lock().unwrap();
            s.incumbents[0].1.budget
        };
        assert_eq!(stored_long, long.ip_budget, "longer-budget solve takes over the seed");
    }

    #[test]
    fn serial_load_under_admission_caps_is_never_shed() {
        let g = chain(6);
        let svc = ConcurrentService::new(1, 4).with_admission(AdmissionLimits {
            max_concurrent: 1,
            max_queue: 0,
            per_tenant: 1,
        });
        let sc = Scenario::new(2, 1, f64::INFINITY);
        // serial requests: each admits, solves, releases — never shed
        let opts = SolveOpts::default();
        svc.plan(&g, &sc, Algorithm::Dp, &opts).unwrap();
        svc.plan(&g, &sc, Algorithm::Dp, &opts).unwrap();
        assert_eq!(svc.shed(), 0, "serial load under the cap must not shed");
        assert_eq!(svc.queue_waits(), 0);
    }
}

//! Concurrent multi-tenant planning service: the `&self`-shareable engine
//! behind [`crate::coordinator::service::PlannerService`] and the serving
//! runtime (DESIGN.md §8).
//!
//! The single-threaded service serializes every tenant behind one `&mut
//! self`; a production planner serves thousands of concurrent
//! heterogeneous [`PlanRequest`]s. [`ConcurrentService`] takes planning to
//! `&self` with three mechanisms, all on `std::sync` (the build stays
//! dependency-free):
//!
//! * **Fingerprint-sharded LRU.** Contexts are keyed by
//!   [`fingerprint_req`] and spread over N shards (`shard = fp % N`), each
//!   an independently locked LRU of `Arc<ProblemCtx>`. The shard lock is
//!   held only for the map operation — never across context construction
//!   or solving — so a cache hit is a position scan + `Arc` clone, and
//!   tenants on different shards never contend at all. The handed-out
//!   `Arc<ProblemCtx>` is itself `Sync`: its `OnceLock` artifact cells
//!   give per-artifact single-flight *within* a context for free.
//! * **Single-flight context construction.** Two concurrent requests with
//!   the same fingerprint build the `ProblemCtx` once: the first becomes
//!   the builder and registers an in-flight entry; later arrivals block on
//!   its condvar and receive the builder's `Arc` — they never clone the
//!   graph or recompute anything ([`ConcurrentService::dedup_waits`]
//!   counts them). The builder publishes into the LRU *before* notifying,
//!   so a waiter's wake always finds the value.
//! * **Budget-keyed incumbent cache.** IP solves store their final
//!   incumbent ([`WarmSeed`]) under `(fingerprint, warm_seed_key)` with
//!   the budget that produced it; a repeat solve of the same problem and
//!   regime resumes from it instead of restarting — a longer-budget
//!   re-solve continues where the short one stopped. Seeding is monotone
//!   (engines take a seed only when strictly better than their own warm
//!   start, and only improve it), so a warm-started solve never returns a
//!   worse objective than a cold one. Seeds are only kept for
//!   LRU-resident fingerprints and are dropped on eviction and
//!   [`ConcurrentService::clear`], so the cache is bounded by
//!   `capacity × |keys|` and can never serve a stale problem.

use crate::algos::PlaceError;
use crate::coordinator::context::{
    fingerprint_req, PlanResult, ProblemCtx, SolveOpts, Solver, WarmSeed,
};
use crate::coordinator::placement::{PlanRequest, Scenario};
use crate::coordinator::planner::{self, Algorithm};
use crate::graph::OpGraph;
use crate::obs;
use crate::workloads::Workload;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One shard's state: an LRU of contexts, the in-flight build registry,
/// and the incumbent seeds of the resident fingerprints.
struct Shard {
    /// Most-recently-used last.
    entries: VecDeque<(u64, Arc<ProblemCtx>)>,
    /// Fingerprints with a context build in flight (tiny: at most the
    /// number of concurrently building tenants on this shard).
    inflight: Vec<(u64, Arc<InFlight>)>,
    /// Budget-keyed incumbent seeds, keyed `(fingerprint,
    /// warm_seed_key)`. Invariant: every fingerprint here is resident in
    /// `entries` (eviction and `clear` drop its seeds with it).
    incumbents: Vec<((u64, u8), SeedEntry)>,
}

/// A context build in progress: waiters block on the condvar until the
/// builder publishes the finished `Arc`.
struct InFlight {
    done: Mutex<Option<Arc<ProblemCtx>>>,
    cv: Condvar,
}

impl InFlight {
    fn new() -> InFlight {
        InFlight { done: Mutex::new(None), cv: Condvar::new() }
    }

    fn wait(&self) -> Arc<ProblemCtx> {
        let mut done = self.done.lock().expect("in-flight lock poisoned");
        loop {
            if let Some(ctx) = done.as_ref() {
                return Arc::clone(ctx);
            }
            done = self.cv.wait(done).expect("in-flight lock poisoned");
        }
    }

    fn publish(&self, ctx: Arc<ProblemCtx>) {
        *self.done.lock().expect("in-flight lock poisoned") = Some(ctx);
        self.cv.notify_all();
    }
}

/// One cached incumbent: the seed, its objective in its own search space,
/// and the solve budget that produced it.
struct SeedEntry {
    seed: WarmSeed,
    objective: f64,
    budget: Duration,
}

/// One shard's registered obs series (DESIGN.md §10): hit/miss/dedup
/// counters plus a plan-latency histogram, labeled `{shard="i"}` so the
/// Prometheus export shows where tenants contend. Handles are resolved
/// once at construction; bumping them is a relaxed atomic op. Instances
/// sharing a shard index share the series — the registry aggregates
/// process-wide.
struct ShardObs {
    hits: Arc<obs::Counter>,
    misses: Arc<obs::Counter>,
    dedup: Arc<obs::Counter>,
    latency_ms: Arc<obs::AtomicHistogram>,
}

impl ShardObs {
    fn new(i: usize) -> ShardObs {
        ShardObs {
            hits: obs::counter(&format!("plan_shard_hits_total{{shard=\"{i}\"}}")),
            misses: obs::counter(&format!("plan_shard_misses_total{{shard=\"{i}\"}}")),
            dedup: obs::counter(&format!("plan_shard_dedup_waits_total{{shard=\"{i}\"}}")),
            latency_ms: obs::histogram(&format!("plan_latency_ms{{shard=\"{i}\"}}")),
        }
    }
}

/// Concurrent, shareable planning service — see the module docs. All
/// planning entry points take `&self`; wrap one in an `Arc` and hand
/// clones to worker threads (or borrow it across a
/// [`std::thread::scope`]).
pub struct ConcurrentService {
    shards: Vec<Mutex<Shard>>,
    /// Parallel to `shards`: the registered per-shard obs series.
    shard_obs: Vec<ShardObs>,
    /// Per-shard LRU capacity (total capacity ÷ shard count, rounded up).
    shard_capacity: usize,
    /// Lattice enumeration cap for the contexts this service creates.
    ideal_cap: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    dedup_waits: AtomicUsize,
}

impl ConcurrentService {
    /// Service over `shards` fingerprint shards caching up to `capacity`
    /// contexts in total (both clamped to ≥ 1), with the default lattice
    /// cap.
    pub fn new(shards: usize, capacity: usize) -> ConcurrentService {
        Self::with_ideal_cap(shards, capacity, crate::graph::ideals::DEFAULT_IDEAL_CAP)
    }

    /// [`ConcurrentService::new`] with an explicit lattice cap for the
    /// contexts it creates (see
    /// [`crate::coordinator::service::PlannerService::with_ideal_cap`]).
    pub fn with_ideal_cap(
        shards: usize,
        capacity: usize,
        ideal_cap: usize,
    ) -> ConcurrentService {
        let shards = shards.max(1);
        ConcurrentService {
            shard_capacity: capacity.max(1).div_ceil(shards),
            shard_obs: (0..shards).map(ShardObs::new).collect(),
            shards: (0..shards)
                .map(|_| {
                    Mutex::new(Shard {
                        entries: VecDeque::new(),
                        inflight: Vec::new(),
                        incumbents: Vec::new(),
                    })
                })
                .collect(),
            ideal_cap,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            dedup_waits: AtomicUsize::new(0),
        }
    }

    fn shard_index(&self, fp: u64) -> usize {
        (fp % self.shards.len() as u64) as usize
    }

    fn shard(&self, fp: u64) -> &Mutex<Shard> {
        &self.shards[self.shard_index(fp)]
    }

    /// The context for `(graph, scenario)` — the scalar adapter entry.
    pub fn context(&self, g: &OpGraph, sc: &Scenario) -> Arc<ProblemCtx> {
        self.context_request(g, &sc.to_request())
    }

    /// The context for `(graph, request)`: cached if its fingerprint is
    /// resident, adopted from a concurrent builder if one is in flight,
    /// freshly built (once, and cached) otherwise. Requests differing only
    /// in solver selectors (objective / contiguity / algorithm) share one
    /// context ([`fingerprint_req`] excludes them).
    pub fn context_request(&self, g: &OpGraph, req: &PlanRequest) -> Arc<ProblemCtx> {
        let fp = fingerprint_req(g, req);
        let sobs = &self.shard_obs[self.shard_index(fp)];
        let shard = self.shard(fp);
        let flight = {
            let mut s = shard.lock().expect("shard lock poisoned");
            if let Some(pos) = s.entries.iter().position(|(key, _)| *key == fp) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                sobs.hits.inc();
                let entry = s.entries.remove(pos).expect("position just found");
                s.entries.push_back(entry.clone());
                return entry.1;
            }
            if let Some(f) = s.inflight.iter().find(|(key, _)| *key == fp) {
                // another tenant is building this exact context right now:
                // wait for its Arc instead of recomputing (single-flight)
                let f = Arc::clone(&f.1);
                drop(s);
                self.dedup_waits.fetch_add(1, Ordering::Relaxed);
                sobs.dedup.inc();
                return f.wait();
            }
            // we are the builder: register before releasing the lock
            self.misses.fetch_add(1, Ordering::Relaxed);
            sobs.misses.inc();
            let f = Arc::new(InFlight::new());
            s.inflight.push((fp, Arc::clone(&f)));
            f
        };
        // build OUTSIDE the shard lock — hits and other builds proceed
        let ctx = Arc::new(ProblemCtx::from_request_with_cap(
            g.clone(),
            req.clone(),
            self.ideal_cap,
        ));
        {
            let mut s = shard.lock().expect("shard lock poisoned");
            s.inflight.retain(|(key, _)| *key != fp);
            s.entries.push_back((fp, Arc::clone(&ctx)));
            while s.entries.len() > self.shard_capacity {
                if let Some((evicted, _)) = s.entries.pop_front() {
                    // satellite invariant: evicting a context drops its
                    // incumbent seeds — the cache stays bounded and can
                    // never seed a fingerprint it no longer holds
                    s.incumbents.retain(|((key, _), _)| *key != evicted);
                }
            }
        }
        flight.publish(Arc::clone(&ctx));
        ctx
    }

    /// The cached incumbent seed for `(fingerprint, key)`, if any.
    fn lookup_seed(&self, fp: u64, key: u8) -> Option<WarmSeed> {
        let s = self.shard(fp).lock().expect("shard lock poisoned");
        s.incumbents
            .iter()
            .find(|((f, k), _)| *f == fp && *k == key)
            .map(|(_, e)| e.seed.clone())
    }

    /// Store a solve's final incumbent under `(fingerprint, key)`, keeping
    /// the best objective seen (ties broken toward the longer budget — a
    /// longer-budget re-solve has explored strictly more of the tree, so
    /// its equal-objective incumbent carries the stronger proof state).
    /// Dropped silently when the fingerprint is no longer LRU-resident.
    fn store_seed(&self, fp: u64, key: u8, seed: &WarmSeed, budget: Duration) {
        let mut s = self.shard(fp).lock().expect("shard lock poisoned");
        if !s.entries.iter().any(|(f, _)| *f == fp) {
            return; // evicted while we were solving: do not resurrect
        }
        let objective = seed.objective();
        match s.incumbents.iter_mut().find(|((f, k), _)| *f == fp && *k == key) {
            Some((_, e)) => {
                let better = objective < e.objective - 1e-12;
                let longer_tie = objective <= e.objective + 1e-12 && budget > e.budget;
                if better || longer_tie {
                    *e = SeedEntry { seed: seed.clone(), objective, budget };
                }
            }
            None => {
                s.incumbents.push(((fp, key), SeedEntry { seed: seed.clone(), objective, budget }));
            }
        }
    }

    /// Plan `(graph, scenario)` with `alg`, reusing every cached artifact.
    /// Seed-free (exactly the sequential service's historical behavior);
    /// the incumbent cache rides [`ConcurrentService::plan_request`].
    pub fn plan(
        &self,
        g: &OpGraph,
        sc: &Scenario,
        alg: Algorithm,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        let ctx = self.context(g, sc);
        alg.solver().solve(&ctx, opts)
    }

    /// Plan a [`PlanRequest`] (fleet + objective + algorithm selection,
    /// `Auto` included), reusing every cached artifact *and* the incumbent
    /// cache: when the request resolves to an IP engine
    /// ([`planner::warm_seed_key`]), the solve resumes from the best prior
    /// incumbent of the same `(problem, regime)` and its own final
    /// incumbent is stored back for the next tenant.
    pub fn plan_request(
        &self,
        g: &OpGraph,
        req: &PlanRequest,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        let _span = obs::span_cat("plan_request", "planner");
        let started = Instant::now();
        let ctx = self.context_request(g, req);
        let key = planner::warm_seed_key(req);
        let result = match key {
            None => planner::solve_request(&ctx, req, opts)?,
            Some(k) => {
                let mut seeded = opts.clone();
                seeded.warm_seed = self.lookup_seed(ctx.fingerprint(), k);
                let result = planner::solve_request(&ctx, req, &seeded)?;
                if let Some(seed) = &result.warm_seed {
                    self.store_seed(ctx.fingerprint(), k, seed, seeded.ip_budget);
                }
                result
            }
        };
        let sobs = &self.shard_obs[self.shard_index(ctx.fingerprint())];
        sobs.latency_ms.observe(started.elapsed().as_secs_f64() * 1e3);
        Ok(result)
    }

    /// [`ConcurrentService::plan`] for a [`Workload`], filling the expert
    /// rule from the workload when the caller didn't set one.
    pub fn plan_workload(
        &self,
        w: &Workload,
        alg: Algorithm,
        opts: &SolveOpts,
    ) -> Result<PlanResult, PlaceError> {
        let mut opts = opts.clone();
        if opts.expert.is_none() {
            opts.expert = w.expert;
        }
        self.plan(&w.graph, &w.scenario, alg, &opts)
    }

    /// Cache hits so far (across all shards).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses so far (= contexts built by this service).
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Requests that adopted a concurrent builder's context instead of
    /// building their own (the single-flight dedup counter).
    pub fn dedup_waits(&self) -> usize {
        self.dedup_waits.load(Ordering::Relaxed)
    }

    /// Cached contexts currently held, across all shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("shard lock poisoned").entries.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Incumbent seeds currently cached, across all shards.
    pub fn seeds_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("shard lock poisoned").incumbents.len())
            .sum()
    }

    /// Drop every cached context AND every incumbent seed (e.g. after an
    /// external cost-model update that invalidates everything). In-flight
    /// builds are not interrupted; they re-insert their (fresh) context on
    /// completion.
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut s = shard.lock().expect("shard lock poisoned");
            s.entries.clear();
            s.incumbents.clear();
        }
    }
}

impl Default for ConcurrentService {
    /// Eight shards × eight contexts each — a serving-sized default.
    fn default() -> ConcurrentService {
        ConcurrentService::new(8, 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::{AlgoChoice, Objective};
    use crate::graph::Node;

    fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(9.0).acc(1.0).mem(1.0).comm(0.2));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }

    #[test]
    fn shared_reference_planning_hits_cache() {
        let g = chain(6);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let svc = ConcurrentService::new(4, 8);
        let a = svc.context(&g, &sc);
        let b = svc.context(&g, &sc);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(svc.hits(), 1);
        assert_eq!(svc.misses(), 1);
        assert_eq!(svc.dedup_waits(), 0);
    }

    #[test]
    fn concurrent_same_fingerprint_builds_once() {
        let g = chain(6);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let svc = ConcurrentService::new(4, 8);
        let ctxs: Vec<Arc<ProblemCtx>> = std::thread::scope(|scope| {
            let handles: Vec<_> =
                (0..8).map(|_| scope.spawn(|| svc.context(&g, &sc))).collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        for c in &ctxs[1..] {
            assert!(Arc::ptr_eq(&ctxs[0], c), "all threads must share one context");
        }
        assert_eq!(svc.misses(), 1, "single-flight: exactly one build");
        assert_eq!(
            svc.hits() + svc.dedup_waits() + svc.misses(),
            8,
            "every request is a hit, a dedup wait, or the one build"
        );
    }

    #[test]
    fn eviction_drops_incumbent_seeds() {
        let g = chain(6);
        // capacity 2, one shard, so a third fingerprint evicts the first
        let svc = ConcurrentService::new(1, 2);
        let opts = SolveOpts { ip_budget: Duration::from_secs(2), ..SolveOpts::default() };
        let req = |k| {
            PlanRequest::new(crate::coordinator::placement::Fleet::uniform(
                k,
                1,
                f64::INFINITY,
            ))
            .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous))
        };
        svc.plan_request(&g, &req(2), &opts).unwrap();
        assert_eq!(svc.seeds_len(), 1, "IP solve must store its incumbent");
        svc.plan_request(&g, &req(3), &opts).unwrap();
        svc.plan_request(&g, &req(4), &opts).unwrap();
        assert_eq!(svc.len(), 2, "capacity bound");
        assert_eq!(svc.seeds_len(), 2, "evicted fingerprint's seed must go with it");
        svc.clear();
        assert!(svc.is_empty());
        assert_eq!(svc.seeds_len(), 0, "clear drops seeds too");
    }

    #[test]
    fn warm_seeded_resolve_is_never_worse_and_identical_when_closed() {
        let g = chain(8);
        let svc = ConcurrentService::new(2, 8);
        // gap 0 ⇒ the IP closes this small instance to proven optimality,
        // making the warm-started re-solve provably identical to the cold
        let opts = SolveOpts {
            ip_budget: Duration::from_secs(10),
            gap_target: 0.0,
            ..SolveOpts::default()
        };
        let req = PlanRequest::new(crate::coordinator::placement::Fleet::uniform(
            2,
            1,
            f64::INFINITY,
        ))
        .objective(Objective::Throughput)
        .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous));
        let cold = svc.plan_request(&g, &req, &opts).unwrap();
        let warm = svc.plan_request(&g, &req, &opts).unwrap();
        assert_eq!(cold.placement.assignment, warm.placement.assignment);
        assert_eq!(
            cold.placement.objective.to_bits(),
            warm.placement.objective.to_bits(),
            "seeded re-solve of a closed instance must be bitwise identical"
        );
    }

    #[test]
    fn longer_budget_resolve_updates_the_stored_seed() {
        let g = chain(6);
        let svc = ConcurrentService::new(1, 4);
        let req = PlanRequest::new(crate::coordinator::placement::Fleet::uniform(
            2,
            1,
            f64::INFINITY,
        ))
        .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous));
        let short = SolveOpts { ip_budget: Duration::from_millis(50), ..SolveOpts::default() };
        let long = SolveOpts { ip_budget: Duration::from_secs(5), ..SolveOpts::default() };
        svc.plan_request(&g, &req, &short).unwrap();
        let fp = fingerprint_req(&g, &req);
        let stored_short = {
            let s = svc.shard(fp).lock().unwrap();
            s.incumbents[0].1.budget
        };
        assert_eq!(stored_short, short.ip_budget);
        svc.plan_request(&g, &req, &long).unwrap();
        let stored_long = {
            let s = svc.shard(fp).lock().unwrap();
            s.incumbents[0].1.budget
        };
        assert_eq!(stored_long, long.ip_budget, "longer-budget solve takes over the seed");
    }
}

//! The coordinator: glues workloads → optimizers → placements → deployment.
//!
//! [`placement`] defines the shared [`placement::Scenario`] /
//! [`placement::Placement`] vocabulary; [`context`] holds the shared
//! per-`(graph, scenario)` analysis cache ([`context::ProblemCtx`]) and the
//! [`context::Solver`] trait every algorithm implements; [`planner`] is the
//! registry + one-call façade (`plan(workload, algorithm)`) used by the
//! CLI, examples and benches; [`service`] is the fingerprint-keyed LRU
//! ([`service::PlannerService`]) that makes serving-time re-planning run at
//! cache-hit cost.

pub mod context;
pub mod placement;
pub mod planner;
pub mod service;

//! The coordinator: glues workloads → optimizers → placements → deployment.
//!
//! [`placement`] defines the shared device vocabulary: the typed
//! heterogeneous [`placement::Fleet`] (device classes with per-class
//! memory caps and speeds) addressed through the unified
//! [`placement::PlanRequest`], the [`placement::Placement`] output, and
//! the deprecated scalar [`placement::Scenario`] adapter; [`context`]
//! holds the shared per-`(graph, request)` analysis cache
//! ([`context::ProblemCtx`]) and the [`context::Solver`] trait every
//! algorithm implements; [`planner`] is the registry + one-call façade
//! (`plan(workload, algorithm)` / `plan_request`) used by the CLI,
//! examples and benches; [`service`] is the fingerprint-keyed LRU
//! ([`service::PlannerService`]) that makes serving-time re-planning —
//! including live fleet mutations — run at cache-hit cost; [`concurrent`]
//! is the `&self`-shareable multi-tenant engine underneath it
//! ([`concurrent::ConcurrentService`]: sharded context LRUs, single-flight
//! context construction, budget-keyed IP incumbent cache).

pub mod concurrent;
pub mod context;
pub mod placement;
pub mod planner;
pub mod service;

//! The coordinator: glues workloads → optimizers → placements → deployment.
//!
//! [`placement`] defines the shared [`placement::Scenario`] /
//! [`placement::Placement`] vocabulary; [`planner`] is the one-call façade
//! (`plan(workload, algorithm)`) used by the CLI, examples and benches.

pub mod placement;
pub mod planner;

//! # dnn-partition
//!
//! A production-grade reproduction of **"Efficient Algorithms for Device
//! Placement of DNN Graph Operators"** (Tarnawski, Phanishayee, Devanur,
//! Mahajan, Nina Paravecino — NeurIPS 2020).
//!
//! Given a DNN computation DAG with per-node CPU/accelerator processing
//! times, memory footprints and transfer costs, plus a deployment
//! description — a heterogeneous device [`coordinator::placement::Fleet`]
//! of typed classes (per-class memory caps and speeds) addressed through
//! the unified [`coordinator::placement::PlanRequest`] API, or the
//! deprecated uniform scalar [`coordinator::placement::Scenario`]
//! (`k` accelerators with one cap `M`, `ℓ` CPUs) — the crate computes
//! **provably optimal device placements** for four regimes:
//!
//! * single-stream inference → latency minimization (IP, Figs. 3–4),
//! * model-parallel training without pipelining (IP + colocation),
//! * pipelined inference → throughput maximization (DP over ideals §5.1.1,
//!   DPL heuristic §5.1.2, IP §5.1.3 incl. non-contiguous splits §5.2),
//! * pipelined training, PipeDream & GPipe schedules (§5.3, Appendices A–C).
//!
//! Everything the paper leans on is implemented in-tree: a bounded-variable
//! revised-simplex LP solver plus branch-and-bound MILP (replacing Gurobi),
//! a Scotch-style multilevel partitioner, local search, PipeDream's
//! linear-chain DP, expert placement rules, workload generators for the
//! paper's seven DNNs at operator and layer granularity, and a
//! discrete-event pipeline simulator that validates the max-load cost model.
//! A three-layer execution runtime (Rust coordinator → JAX model → Pallas
//! attention kernel, AOT-compiled to HLO and executed through PJRT) serves
//! partitioned models for real, end to end.
//!
//! ## Layout
//!
//! * [`graph`] — the computational model of §3 and its algorithms
//!   (ideals, contiguity, contraction).
//! * [`algos`] — the paper's optimizers (DP / DPL / IP, training variants,
//!   Appendix-C extensions).
//! * [`solver`] — the from-scratch LP/MILP engine backing the IPs.
//! * [`baselines`] — greedy / Scotch-like / local search / PipeDream / expert.
//! * [`workloads`] — BERT, ResNet50, Inception-v3, GNMT generators and the
//!   paper's JSON interchange format.
//! * [`topo`] — device-interconnect topology: per-device-pair
//!   bandwidth/latency matrices with hierarchical constructors
//!   (uniform / islands / tiered / explicit matrix), the canonical
//!   `pair_cost` accessor every comm-cost site routes through, and the
//!   `topo=` clause of the `--fleet` grammar (DESIGN.md §9).
//! * [`simx`] — fleet-aware discrete-event simulation: typed-event engine
//!   (compute/transfer/fault/straggler/recovery/load-spike), live
//!   memory-occupancy accounting, prediction-vs-simulation validation,
//!   the drift-driven re-planning loop (DESIGN.md §6), and the serving
//!   resilience layer — [`simx::controller`]'s hysteresis
//!   re-plan/failover/shed ladder driven by [`runtime::health`]'s
//!   drift-and-probe state machine, fuzzed by [`simx::chaos`] campaigns
//!   (DESIGN.md §7).
//! * [`pipeline`] — legacy uniform-scenario façade over the `simx` engine
//!   (Figs. 2/5/7 schedules).
//! * [`obs`] — the unified observability layer (DESIGN.md §10): RAII
//!   spans, registered counters, fixed-bucket log2 histograms, and the
//!   Chrome-trace / Prometheus / JSON exporters behind the `stats` CLI
//!   subcommand and `--profile` trace files.
//! * [`runtime`] + [`coordinator`] — PJRT stage executor and the pipelined
//!   serving loop; [`coordinator::context`] is the shared per-problem
//!   analysis cache every solver plugs into (the [`coordinator::context::Solver`]
//!   trait), [`coordinator::service`] the fingerprint-keyed planning
//!   service that re-plans scenario changes at cache-hit cost (see
//!   DESIGN.md §4).

pub mod algos;
pub mod baselines;
pub mod coordinator;
pub mod graph;
pub mod obs;
pub mod pipeline;
pub mod runtime;
pub mod simx;
pub mod solver;
pub mod topo;
pub mod util;
pub mod workloads;

/// Common imports for downstream users.
pub mod prelude {
    pub use crate::coordinator::placement::{
        DeviceClass, Fleet, Placement, PlanRequest, Scenario,
    };
    pub use crate::graph::{Node, NodeId, NodeKind, OpGraph};
    pub use crate::util::bitset::BitSet;
}

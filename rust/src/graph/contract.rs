//! Appendix-B preprocessing: colocation contraction, SCC contraction, and
//! the forward-mirror construction for orphaned backward nodes.
//!
//! Training graphs carry colocation constraints (`colorClass`): forward and
//! backward ops sharing weights must land on one device. The DP operates on
//! a *contracted* graph where each forward color class and each backward
//! color class is a single node; contraction can create cycles, whose SCCs
//! are then contracted too (any colocation-respecting contiguous split must
//! keep an SCC together). A [`Contraction`] remembers the node mapping so
//! placements on the contracted graph can be expanded back.

use super::{Node, NodeId, NodeKind, OpGraph};
use std::collections::BTreeMap;

/// Result of contracting a graph: the smaller graph plus the mapping from
/// original node to contracted node.
pub struct Contraction {
    pub graph: OpGraph,
    /// `map[orig] = contracted node id`.
    pub map: Vec<NodeId>,
    /// Reverse mapping: original nodes merged into each contracted node.
    pub groups: Vec<Vec<NodeId>>,
}

impl Contraction {
    /// Expand a per-contracted-node device assignment back to the original
    /// graph's nodes.
    pub fn expand_assignment(&self, device_of_contracted: &[usize]) -> Vec<usize> {
        self.map.iter().map(|&c| device_of_contracted[c]).collect()
    }
}

/// Merge nodes into groups given by `group_of[v]` (same value ⇒ merged).
/// Costs are summed; `comm` of a merged node is the sum of member comms
/// whose outputs leave the group (approximation consistent with App. B);
/// memory and processing times add up. Edges are deduplicated; self-loops
/// dropped. Per-edge costs are summed across merged parallel edges.
pub fn contract_groups(g: &OpGraph, group_of: &[usize]) -> Contraction {
    let num_groups = group_of.iter().copied().max().map_or(0, |m| m + 1);
    let mut groups: Vec<Vec<NodeId>> = vec![Vec::new(); num_groups];
    for (v, &grp) in group_of.iter().enumerate() {
        groups[grp].push(v);
    }

    let mut out = OpGraph::new();
    for (gi, members) in groups.iter().enumerate() {
        assert!(!members.is_empty(), "empty contraction group {gi}");
        let mut node = Node::new(contracted_name(g, members));
        node.p_cpu = members.iter().map(|&v| g.nodes[v].p_cpu).sum();
        node.p_acc = members.iter().map(|&v| g.nodes[v].p_acc).sum();
        node.mem = members.iter().map(|&v| g.nodes[v].mem).sum();
        // comm = sum of member outputs crossing the group boundary
        node.comm = members
            .iter()
            .filter(|&&v| g.succs[v].iter().any(|&w| group_of[w] != gi))
            .map(|&v| g.nodes[v].comm)
            .sum();
        // group is backward iff all members are backward
        node.kind = if members.iter().all(|&v| g.nodes[v].kind == NodeKind::Backward) {
            NodeKind::Backward
        } else {
            NodeKind::Forward
        };
        // keep first color class for reference (colocation already encoded
        // in the contraction itself)
        node.color_class = g.nodes[members[0]].color_class;
        out.add_node(node);
    }

    let mut edge_costs: BTreeMap<(NodeId, NodeId), f64> = BTreeMap::new();
    for (u, v) in g.edges() {
        let (gu, gv) = (group_of[u], group_of[v]);
        if gu != gv {
            out.add_edge(gu, gv);
            if let Some(&c) = g.edge_costs.get(&(u, v)) {
                *edge_costs.entry((gu, gv)).or_insert(0.0) += c;
            }
        }
    }
    out.edge_costs = edge_costs;

    Contraction { graph: out, map: group_of.to_vec(), groups }
}

fn contracted_name(g: &OpGraph, members: &[NodeId]) -> String {
    if members.len() == 1 {
        g.nodes[members[0]].name.clone()
    } else {
        format!("{}+{}", g.nodes[members[0]].name, members.len() - 1)
    }
}

/// Contract color classes, separately for forward and backward members
/// (App. B: contract each `C_FW` and each `C_BW`).
pub fn contract_color_classes(g: &OpGraph) -> Contraction {
    // group key: (colorClass, kind) or unique id for uncolored nodes
    let mut key_to_group: BTreeMap<(u32, bool), usize> = BTreeMap::new();
    let mut group_of = vec![usize::MAX; g.n()];
    let mut next = 0;
    for (v, node) in g.nodes.iter().enumerate() {
        match node.color_class {
            Some(c) => {
                let key = (c, node.kind == NodeKind::Backward);
                let grp = *key_to_group.entry(key).or_insert_with(|| {
                    let id = next;
                    next += 1;
                    id
                });
                group_of[v] = grp;
            }
            None => {
                group_of[v] = next;
                next += 1;
            }
        }
    }
    contract_groups(g, &group_of)
}

/// Tarjan SCC (iterative). Returns `scc_of[v]`, with components numbered in
/// reverse topological order of the condensation.
pub fn sccs(g: &OpGraph) -> Vec<usize> {
    let n = g.n();
    let mut index = vec![usize::MAX; n];
    let mut low = vec![0usize; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<usize> = Vec::new();
    let mut scc_of = vec![usize::MAX; n];
    let mut next_index = 0;
    let mut next_scc = 0;

    // Explicit DFS stack: (node, next-succ-cursor)
    for start in 0..n {
        if index[start] != usize::MAX {
            continue;
        }
        let mut dfs: Vec<(usize, usize)> = vec![(start, 0)];
        index[start] = next_index;
        low[start] = next_index;
        next_index += 1;
        stack.push(start);
        on_stack[start] = true;

        while let Some(top) = dfs.last_mut() {
            let (v, ci) = (top.0, top.1);
            if ci < g.succs[v].len() {
                top.1 += 1;
                let w = g.succs[v][ci];
                if index[w] == usize::MAX {
                    index[w] = next_index;
                    low[w] = next_index;
                    next_index += 1;
                    stack.push(w);
                    on_stack[w] = true;
                    dfs.push((w, 0));
                } else if on_stack[w] {
                    low[v] = low[v].min(index[w]);
                }
            } else {
                if low[v] == index[v] {
                    loop {
                        let w = stack.pop().unwrap();
                        on_stack[w] = false;
                        scc_of[w] = next_scc;
                        if w == v {
                            break;
                        }
                    }
                    next_scc += 1;
                }
                dfs.pop();
                if let Some(parent) = dfs.last() {
                    let p = parent.0;
                    low[p] = low[p].min(low[v]);
                }
            }
        }
    }
    scc_of
}

/// App.-B full pipeline: contract color classes, then contract any SCCs the
/// colocation contraction introduced, yielding an acyclic contracted graph.
/// The composite mapping goes original node → final contracted node.
pub fn preprocess_colocation(g: &OpGraph) -> Contraction {
    let c1 = contract_color_classes(g);
    let scc_of = sccs(&c1.graph);
    let c2 = contract_groups(&c1.graph, &scc_of);
    // compose mappings
    let map: Vec<NodeId> = c1.map.iter().map(|&m| c2.map[m]).collect();
    let mut groups: Vec<Vec<NodeId>> = vec![Vec::new(); c2.graph.n()];
    for (v, &m) in map.iter().enumerate() {
        groups[m].push(v);
    }
    Contraction { graph: c2.graph, map, groups }
}

/// App.-B orphan mirroring for training DP: every backward node must have a
/// forward partner; for orphaned backward nodes, insert artificial
/// zero-cost forward nodes (colocated with the orphan) and mirror the
/// backward edges as reversed forward edges so the ideal lattice does not
/// blow up and backward contiguity is preserved.
///
/// Returns the augmented graph plus `bw_of_fw[f] = Some(b)` linking each
/// forward node to the backward node whose costs ride along with it.
pub fn mirror_orphans(g: &OpGraph) -> (OpGraph, Vec<Option<NodeId>>) {
    let mut out = g.clone();
    // forward partner of each backward node, via fw_partner metadata
    let mut fw_of_bw: Vec<Option<NodeId>> = vec![None; g.n()];
    for (v, node) in g.nodes.iter().enumerate() {
        if node.kind == NodeKind::Backward {
            fw_of_bw[v] = node.fw_partner;
        }
    }
    // create artificial forward images for orphans
    let mut image: Vec<Option<NodeId>> = vec![None; g.n()];
    for v in 0..g.n() {
        if g.nodes[v].kind == NodeKind::Backward && fw_of_bw[v].is_none() {
            let mut art = Node::new(format!("fwimg_{}", g.nodes[v].name));
            art.p_cpu = 0.0;
            art.p_acc = 0.0;
            art.mem = 0.0;
            art.comm = 0.0;
            art.color_class = g.nodes[v].color_class;
            let id = out.add_node(art);
            image[v] = Some(id);
        }
    }
    // mirror backward edges (u', v') with an orphan endpoint as forward
    // edge (img(v'), img(u')) — reversed, per App. B.
    let fw_image = |w: NodeId, image: &[Option<NodeId>], fw_of_bw: &[Option<NodeId>]| {
        image.get(w).copied().flatten().or(fw_of_bw.get(w).copied().flatten())
    };
    for (u, v) in g.edges() {
        let ub = g.nodes[u].kind == NodeKind::Backward;
        let vb = g.nodes[v].kind == NodeKind::Backward;
        if ub && vb && (fw_of_bw[u].is_none() || fw_of_bw[v].is_none()) {
            if let (Some(iu), Some(iv)) = (fw_image(u, &image, &fw_of_bw), fw_image(v, &image, &fw_of_bw)) {
                if iu != iv {
                    out.add_edge(iv, iu); // reversed
                }
            }
        }
    }
    // bw_of_fw over the augmented node space
    let mut bw_of_fw: Vec<Option<NodeId>> = vec![None; out.n()];
    for v in 0..g.n() {
        if g.nodes[v].kind == NodeKind::Backward {
            if let Some(f) = fw_of_bw[v].or(image[v]) {
                bw_of_fw[f] = Some(v);
            }
        }
    }
    (out, bw_of_fw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::is_dag;

    fn colored_path() -> OpGraph {
        // 0 -> 1 -> 2, where 0 and 2 share a color class
        let mut g = OpGraph::new();
        g.add_node(Node::new("a").cpu(1.0).acc(1.0).color(7));
        g.add_node(Node::new("b").cpu(2.0).acc(2.0));
        g.add_node(Node::new("c").cpu(4.0).acc(4.0).color(7));
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g
    }

    #[test]
    fn color_contraction_creates_cycle_then_scc_fixes_it() {
        let g = colored_path();
        let c1 = contract_color_classes(&g);
        assert_eq!(c1.graph.n(), 2);
        assert!(!is_dag(&c1.graph)); // {a,c} <-> {b}
        let full = preprocess_colocation(&g);
        assert_eq!(full.graph.n(), 1); // everything must be colocated
        assert!(is_dag(&full.graph));
        assert!((full.graph.nodes[0].p_cpu - 7.0).abs() < 1e-9);
        assert_eq!(full.map, vec![0, 0, 0]);
    }

    #[test]
    fn contraction_sums_costs_and_dedups_edges() {
        // 0,1 same group; both have edges to 2
        let mut g = OpGraph::new();
        g.add_node(Node::new("a").cpu(1.0).acc(1.5).mem(2.0).comm(0.25).color(1));
        g.add_node(Node::new("b").cpu(2.0).acc(2.5).mem(3.0).comm(0.75).color(1));
        g.add_node(Node::new("c").cpu(1.0).acc(1.0));
        g.add_edge(0, 2);
        g.add_edge(1, 2);
        let c = contract_color_classes(&g);
        assert_eq!(c.graph.n(), 2);
        assert_eq!(c.graph.num_edges(), 1);
        let merged = &c.graph.nodes[c.map[0]];
        assert!((merged.p_cpu - 3.0).abs() < 1e-9);
        assert!((merged.mem - 5.0).abs() < 1e-9);
        // both outputs cross the boundary → comm sums
        assert!((merged.comm - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scc_on_dag_is_identity_partition() {
        let g = crate::graph::test_graphs::diamond();
        let s = sccs(&g);
        let mut uniq = s.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 4);
    }

    #[test]
    fn scc_detects_cycle() {
        let mut g = OpGraph::new();
        for i in 0..3 {
            g.add_node(Node::new(format!("n{i}")));
        }
        g.add_edge(0, 1);
        g.add_edge(1, 0);
        g.add_edge(1, 2);
        let s = sccs(&g);
        assert_eq!(s[0], s[1]);
        assert_ne!(s[0], s[2]);
    }

    #[test]
    fn expand_assignment_roundtrip() {
        let g = colored_path();
        let c = preprocess_colocation(&g);
        let devices = c.expand_assignment(&[3]);
        assert_eq!(devices, vec![3, 3, 3]);
    }

    #[test]
    fn mirror_orphans_adds_images() {
        // fw: 0 -> 1 ; bw: 2(partner of 1) -> 3(orphan)
        let mut g = OpGraph::new();
        g.add_node(Node::new("f0"));
        g.add_node(Node::new("f1"));
        let mut b2 = Node::new("b2").backward();
        b2.fw_partner = Some(1);
        g.add_node(b2);
        g.add_node(Node::new("b3").backward());
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(2, 3);
        let (aug, bw_of_fw) = mirror_orphans(&g);
        assert_eq!(aug.n(), 5); // one artificial forward image for b3
        assert!(is_dag(&aug));
        // image node (id 4) gets the reversed edge 4 -> 1
        assert!(aug.succs[4].contains(&1));
        assert_eq!(bw_of_fw[1], Some(2));
        assert_eq!(bw_of_fw[4], Some(3));
        assert_eq!(aug.nodes[4].p_acc, 0.0);
    }
}

//! Computational-model substrate (paper §3).
//!
//! An [`OpGraph`] is the DAG `G=(V,E)` of DNN operators (or layers) with the
//! paper's per-node weights:
//!
//! * `p_cpu`  — processing time on a CPU core,
//! * `p_acc`  — processing time on an accelerator (`f64::INFINITY` when the
//!   op is unsupported there),
//! * `mem`    — memory footprint of weights + activations,
//! * `comm`   — cost of moving the node's output across the host↔accelerator
//!   boundary (paid once per crossing direction, per §3),
//! * `color_class` — colocation group (App. B): nodes sharing a class must
//!   land on the same device (e.g. forward and backward ops on one weight),
//! * `kind`   — forward / backward, used by the training algorithms (§5.3).
//!
//! Submodules implement the graph algorithms the optimizers stand on:
//! topology ([`topo`]), the ideal lattice ([`ideals`]), contiguity checks
//! ([`contiguity`]), the App.-B contraction pipeline ([`contract`]), and the
//! per-edge-cost reduction ([`subdivide`]).

pub mod contiguity;
pub mod contract;
pub mod ideals;
pub mod subdivide;
pub mod topo;

use crate::util::bitset::BitSet;

/// Index of a node in an [`OpGraph`].
pub type NodeId = usize;

/// Forward- or backward-pass node (all-inference graphs are all `Forward`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    Forward,
    Backward,
}

/// One operator (or layer) and its cost-model weights.
#[derive(Clone, Debug)]
pub struct Node {
    pub name: String,
    /// Processing time on a CPU core (`p_v^cpu`).
    pub p_cpu: f64,
    /// Processing time on an accelerator (`p_v^acc`); `INFINITY` = unsupported.
    pub p_acc: f64,
    /// Memory usage of weights + activations (`m_v`).
    pub mem: f64,
    /// Host↔accelerator transfer time of this node's output (`c_v`).
    pub comm: f64,
    /// Colocation class (App. B `colorClass`): same class ⇒ same device.
    pub color_class: Option<u32>,
    pub kind: NodeKind,
    /// For a backward node, its forward partner (if any). Kept as metadata —
    /// colocation itself is expressed through `color_class`.
    pub fw_partner: Option<NodeId>,
}

impl Node {
    /// A forward node with uniform defaults; builder-style setters below.
    pub fn new(name: impl Into<String>) -> Self {
        Node {
            name: name.into(),
            p_cpu: 1.0,
            p_acc: 1.0,
            mem: 0.0,
            comm: 0.0,
            color_class: None,
            kind: NodeKind::Forward,
            fw_partner: None,
        }
    }

    pub fn cpu(mut self, t: f64) -> Self {
        self.p_cpu = t;
        self
    }

    pub fn acc(mut self, t: f64) -> Self {
        self.p_acc = t;
        self
    }

    pub fn mem(mut self, m: f64) -> Self {
        self.mem = m;
        self
    }

    pub fn comm(mut self, c: f64) -> Self {
        self.comm = c;
        self
    }

    pub fn color(mut self, c: u32) -> Self {
        self.color_class = Some(c);
        self
    }

    pub fn backward(mut self) -> Self {
        self.kind = NodeKind::Backward;
        self
    }
}

/// The computation DAG with adjacency in both directions.
#[derive(Clone, Debug, Default)]
pub struct OpGraph {
    pub nodes: Vec<Node>,
    /// `succs[u]` = nodes v with an edge (u, v).
    pub succs: Vec<Vec<NodeId>>,
    /// `preds[v]` = nodes u with an edge (u, v).
    pub preds: Vec<Vec<NodeId>>,
    /// Optional per-edge communication costs keyed `(u, v)`; when present
    /// and non-uniform, [`subdivide::reduce_edge_costs`] converts them to
    /// the per-node `comm` model (App. B reduction).
    pub edge_costs: std::collections::BTreeMap<(NodeId, NodeId), f64>,
}

impl OpGraph {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_node(&mut self, node: Node) -> NodeId {
        self.nodes.push(node);
        self.succs.push(Vec::new());
        self.preds.push(Vec::new());
        self.nodes.len() - 1
    }

    /// Add edge `u -> v`. Duplicate edges are ignored (workload exporters
    /// occasionally emit them).
    pub fn add_edge(&mut self, u: NodeId, v: NodeId) {
        assert!(u < self.nodes.len() && v < self.nodes.len(), "edge endpoint out of range");
        assert_ne!(u, v, "self-loop");
        if !self.succs[u].contains(&v) {
            self.succs[u].push(v);
            self.preds[v].push(u);
        }
    }

    /// Add edge with an explicit per-edge communication cost.
    pub fn add_edge_cost(&mut self, u: NodeId, v: NodeId, cost: f64) {
        self.add_edge(u, v);
        self.edge_costs.insert((u, v), cost);
    }

    pub fn n(&self) -> usize {
        self.nodes.len()
    }

    pub fn num_edges(&self) -> usize {
        self.succs.iter().map(|s| s.len()).sum()
    }

    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.succs.iter().enumerate().flat_map(|(u, vs)| vs.iter().map(move |&v| (u, v)))
    }

    /// Total memory of a node set.
    pub fn mem_of(&self, set: &BitSet) -> f64 {
        set.iter().map(|v| self.nodes[v].mem).sum()
    }

    /// Sum of CPU processing times of a node set (`cpu(S)` in §5.1.1).
    pub fn cpu_load(&self, set: &BitSet) -> f64 {
        // speed 1.0 divides exactly: bitwise the plain sum
        self.cpu_load_scaled(set, 1.0)
    }

    /// Accelerator load `acc(S)` of §5.1.1: in-communication + processing +
    /// out-communication. Returns `INFINITY` if the set exceeds `mem_cap`
    /// or contains an accelerator-unsupported op.
    ///
    /// * in-comm: `Σ c_u` over u ∉ S with an edge into S (each such u paid
    ///   once, even with several edges into S);
    /// * out-comm: `Σ c_v` over v ∈ S with an edge leaving S.
    pub fn acc_load(&self, set: &BitSet, mem_cap: f64) -> f64 {
        // speed 1.0 divides exactly: bitwise the unscaled form
        self.acc_load_scaled(set, mem_cap, 1.0)
    }

    /// [`OpGraph::cpu_load`] on a device of relative `speed` (processing
    /// times divide by the speed).
    pub fn cpu_load_scaled(&self, set: &BitSet, speed: f64) -> f64 {
        set.iter().map(|v| self.nodes[v].p_cpu / speed).sum()
    }

    /// [`OpGraph::acc_load`] on an accelerator of relative `speed`:
    /// compute divides by the speed, boundary communication does not.
    pub fn acc_load_scaled(&self, set: &BitSet, mem_cap: f64, speed: f64) -> f64 {
        if self.mem_of(set) > mem_cap {
            return f64::INFINITY;
        }
        let mut load = 0.0;
        let mut in_paid = BitSet::new(self.n());
        for v in set.iter() {
            let p = self.nodes[v].p_acc;
            if p.is_infinite() {
                return f64::INFINITY;
            }
            load += p / speed;
            for &u in &self.preds[v] {
                if !set.contains(u) && !in_paid.contains(u) {
                    in_paid.insert(u);
                    load += self.nodes[u].comm;
                }
            }
            if self.succs[v].iter().any(|&w| !set.contains(w)) {
                load += self.nodes[v].comm;
            }
        }
        load
    }

    /// Number of forward nodes (convenience for training graphs).
    pub fn num_forward(&self) -> usize {
        self.nodes.iter().filter(|n| n.kind == NodeKind::Forward).count()
    }

    /// All-nodes set.
    pub fn full_set(&self) -> BitSet {
        BitSet::full(self.n())
    }

    /// Graphviz DOT rendering with nodes colored by a device assignment
    /// (used to regenerate Fig. 9). `device[v] = 0` means CPU (red), `i>0`
    /// an accelerator.
    pub fn to_dot(&self, device: &[usize], title: &str) -> String {
        const PALETTE: [&str; 8] = [
            "#e41a1c", // CPU = red, as in Fig. 9
            "#377eb8", "#4daf4a", "#984ea3", "#ff7f00", "#a65628", "#f781bf", "#999999",
        ];
        let mut out = String::new();
        out.push_str(&format!("digraph \"{}\" {{\n  rankdir=TB;\n  node [style=filled];\n", title));
        for (v, node) in self.nodes.iter().enumerate() {
            let color = PALETTE[device.get(v).copied().unwrap_or(0) % PALETTE.len()];
            out.push_str(&format!(
                "  n{} [label=\"{}\", fillcolor=\"{}\"];\n",
                v, node.name, color
            ));
        }
        for (u, v) in self.edges() {
            out.push_str(&format!("  n{} -> n{};\n", u, v));
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
pub(crate) mod test_graphs {
    use super::*;

    /// Diamond: 0 -> {1, 2} -> 3.
    pub fn diamond() -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("n{i}")).cpu(2.0).acc(1.0).mem(1.0).comm(0.5));
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        g
    }

    /// Chain of `n` nodes.
    pub fn chain(n: usize) -> OpGraph {
        let mut g = OpGraph::new();
        for i in 0..n {
            g.add_node(Node::new(format!("c{i}")).cpu(2.0).acc(1.0).mem(1.0).comm(0.5));
        }
        for i in 1..n {
            g.add_edge(i - 1, i);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::test_graphs::*;
    use super::*;

    #[test]
    fn build_and_count() {
        let g = diamond();
        assert_eq!(g.n(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.preds[3], vec![1, 2]);
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut g = chain(2);
        g.add_edge(0, 1);
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn acc_load_counts_boundary_comm_once() {
        let g = diamond();
        // S = {1, 2}: in-comm pays c_0 once (0 has edges to both 1 and 2),
        // out-comm pays c_1 + c_2, processing = 1 + 1.
        let s = BitSet::from_iter(4, [1, 2]);
        let load = g.acc_load(&s, f64::INFINITY);
        assert!((load - (0.5 + 1.0 + 1.0 + 0.5 + 0.5)).abs() < 1e-9);
    }

    #[test]
    fn acc_load_memory_cap() {
        let g = diamond();
        let s = BitSet::from_iter(4, [1, 2]);
        assert!(g.acc_load(&s, 1.5).is_infinite());
        assert!(g.acc_load(&s, 2.0).is_finite());
    }

    #[test]
    fn acc_load_unsupported_op() {
        let mut g = diamond();
        g.nodes[1].p_acc = f64::INFINITY;
        let s = BitSet::from_iter(4, [1]);
        assert!(g.acc_load(&s, f64::INFINITY).is_infinite());
    }

    #[test]
    fn cpu_load_sums() {
        let g = chain(5);
        let s = BitSet::from_iter(5, [0, 2, 4]);
        assert!((g.cpu_load(&s) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn dot_renders() {
        let g = diamond();
        let dot = g.to_dot(&[0, 1, 2, 1], "t");
        assert!(dot.contains("digraph"));
        assert!(dot.contains("n0 -> n1"));
    }
}

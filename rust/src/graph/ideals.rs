//! The ideal lattice of a DAG (paper §5.1.1).
//!
//! An *ideal* (Definition 5.1) is a downward-closed node set: if `(u,v) ∈ E`
//! and `v ∈ I` then `u ∈ I`. Ideals are exactly the possible "already
//! partitioned" prefixes of the throughput DP, and by Fact 5.2 every
//! contiguous set is a difference `I \ I'` of two nested ideals.
//!
//! ## Memory layout (the `O(𝓘²(V+E))` bottleneck, made cache-friendly)
//!
//! With up to millions of ideals (Table 1), per-ideal `BitSet` allocations
//! and a `HashMap<BitSet, IdealId>` dominated both time and memory. The
//! lattice now lives in a single flat word arena ([`SetArena`]): every
//! ideal is a fixed-stride `&[u64]` slice, deduplication goes through an
//! open-addressing [`InternTable`] on precomputed 64-bit hashes, and the
//! BFS stages each candidate directly in the arena (push, dedup, keep or
//! pop) — **zero per-ideal heap allocations** in the enumeration hot loop.
//!
//! Enumeration is a FIFO BFS that extends each ideal by the nodes of its
//! *addable frontier* (complement nodes whose predecessors are all inside),
//! maintained incrementally: extending `I` by `v` shrinks the frontier by
//! `v` and grows it by exactly those successors of `v` whose last missing
//! predecessor was `v` — no rescan of all `n` nodes per ideal. FIFO order
//! yields ideals sorted by cardinality for free (every ideal is created
//! from a parent one element smaller), which the DP consumes as
//! *level-synchronous layers* ([`IdealLattice::layer`]) that can be solved
//! in parallel.
//!
//! For each ideal the list of its *immediate* sub-ideals (remove one
//! maximal element) is stored in CSR form ([`IdealLattice::subs`]); the DP
//! walks arbitrary nested pairs `I' ⊆ I` downward through these links.

use super::{NodeId, OpGraph};
use crate::util::arena::{self, InternTable, SetArena};
use crate::util::bitset::BitSet;

/// Dense id of an ideal within a lattice.
pub type IdealId = usize;

/// Hard cap to protect against graphs with exponentially many ideals
/// (e.g. wide antichains). Enumeration aborts with `Err(count_so_far)`.
pub const DEFAULT_IDEAL_CAP: usize = 2_000_000;

/// A borrowed view of one ideal: a word slice in the lattice arena.
#[derive(Clone, Copy)]
pub struct IdealRef<'a> {
    words: &'a [u64],
    capacity: usize,
}

impl<'a> IdealRef<'a> {
    #[inline]
    pub fn contains(&self, v: usize) -> bool {
        arena::word_contains(self.words, v)
    }

    /// Iterate members in increasing order.
    pub fn iter(&self) -> arena::WordBits<'a> {
        arena::bits(self.words)
    }

    /// Cardinality (word-fused popcount; prefer [`IdealLattice::card`],
    /// which is precomputed, on hot paths).
    pub fn len(&self) -> usize {
        arena::popcount(self.words)
    }

    pub fn is_empty(&self) -> bool {
        !arena::any(self.words)
    }

    #[inline]
    pub fn words(&self) -> &'a [u64] {
        self.words
    }

    /// Materialize as an owned [`BitSet`] (cold paths / tests).
    pub fn to_bitset(&self) -> BitSet {
        BitSet::from_words(self.capacity, self.words)
    }
}

pub struct IdealLattice {
    /// All ideal rows, in enumeration (= cardinality) order: row 0 is ∅,
    /// the last row is the full node set.
    arena: SetArena,
    /// Cardinality of each ideal (cached — no popcounts on hot paths).
    cards: Vec<u32>,
    /// `layer_start[c]..layer_start[c+1]` = ids of the ideals with
    /// cardinality `c`.
    layer_start: Vec<usize>,
    /// CSR offsets into `sub_list` (len = number of ideals + 1).
    sub_off: Vec<usize>,
    /// Flattened immediate-sub-ideal links `(sub_id, removed_node)`.
    sub_list: Vec<(u32, u32)>,
    /// Row-content → id interning table (kept for [`IdealLattice::id_of`]).
    table: InternTable,
    /// Number of graph nodes (= bit capacity of every row).
    n: usize,
}

/// Shared BFS core: enumerate all ideals into an arena with incremental
/// addable frontiers. Returns `(rows, intern table, cardinalities,
/// links)`; errors with the would-be id when the `cap` is exceeded.
///
/// With `record_links`, every staged candidate — fresh or deduplicated —
/// is recorded as a `(child, parent, added_node)` triple. These are
/// exactly the immediate sub-ideal links: staging `I ∪ {v}` makes `v`
/// maximal in the child (a successor of `v` inside `I` would put `v` in
/// `I` by downward closure), and conversely for any maximal `v` of an
/// ideal `J`, the BFS stages `(J \ {v}) ∪ {v}` when it processes
/// `J \ {v}`. So the CSR can be built by bucketing, with no re-hashing.
fn enumerate_core(
    g: &OpGraph,
    cap: usize,
    record_links: bool,
) -> Result<(SetArena, InternTable, Vec<u32>, Vec<(u32, u32, u32)>), usize> {
    let n = g.n();
    let mut rows = SetArena::with_row_capacity(n, 1024);
    // addable frontier of each ideal, row-parallel to `rows`; dropped after
    // the BFS (only `rows` outlives this function)
    let mut frontiers = SetArena::with_row_capacity(n, 1024);
    let mut table = InternTable::with_capacity(1024);
    let mut cards: Vec<u32> = Vec::new();
    let mut links: Vec<(u32, u32, u32)> = Vec::new();

    rows.push_empty();
    let (root, fresh) = table.intern_last(&mut rows);
    debug_assert!(fresh && root == 0);
    cards.push(0);
    let f0 = frontiers.push_empty();
    for v in 0..n {
        if g.preds[v].is_empty() {
            frontiers.set_bit(f0, v);
        }
    }

    // FIFO scan: every new ideal is one element bigger than its parent, so
    // processing in creation order visits (and creates) ideals in
    // non-decreasing cardinality order — no sort pass afterwards.
    //
    // A frontier row is dead the moment its ideal is dequeued, so the
    // frontier arena is run as a queue: ideal `id`'s frontier lives at row
    // `id - fr_base`, and the dead prefix is compacted away once it
    // dominates — peak frontier memory is O(queue backlog), not O(𝓘).
    let mut cur_frontier: Vec<u64> = vec![0; rows.stride()];
    let mut head = 0usize;
    let mut fr_base = 0usize;
    while head < rows.len() {
        let id = head;
        head += 1;
        if head - fr_base > frontiers.len() / 2 && head - fr_base > 1024 {
            frontiers.discard_front(head - 1 - fr_base);
            fr_base = head - 1;
        }
        cur_frontier.copy_from_slice(frontiers.row(id - fr_base));
        let card = cards[id];
        for v in arena::bits(&cur_frontier) {
            // stage I ∪ {v} at the end of the arena, dedup, keep or discard
            let staged = rows.push_copy(id);
            rows.set_bit(staged, v);
            let (nid, fresh) = table.intern_last(&mut rows);
            if record_links {
                links.push((nid, id as u32, v as u32));
            }
            if !fresh {
                continue;
            }
            let nid = nid as usize;
            if nid >= cap {
                return Err(nid);
            }
            cards.push(card + 1);
            // frontier(I ∪ {v}) = (frontier(I) \ {v}) ∪ {w ∈ succs(v) :
            // preds(w) ⊆ I ∪ {v}} — adding a node never removes other
            // addable nodes.
            let fr = frontiers.push_copy(id - fr_base);
            debug_assert_eq!(fr + fr_base, nid);
            frontiers.clear_bit(fr, v);
            for &w in &g.succs[v] {
                if g.preds[w].iter().all(|&u| rows.contains(nid, u)) {
                    frontiers.set_bit(fr, w);
                }
            }
        }
    }
    Ok((rows, table, cards, links))
}

impl IdealLattice {
    /// Enumerate every ideal of `g`. Errors with the number seen so far if
    /// more than `cap` ideals exist — callers fall back to DPL (§5.1.2).
    pub fn enumerate(g: &OpGraph, cap: usize) -> Result<IdealLattice, usize> {
        crate::util::counters::bump_enumerate();
        let (rows, table, cards, links) = enumerate_core(g, cap, true)?;
        let n = g.n();
        let ni = rows.len();

        // layer index over the (already sorted) cardinalities
        let max_card = *cards.last().unwrap_or(&0) as usize;
        let mut layer_start = vec![0usize; max_card + 2];
        for &c in &cards {
            layer_start[c as usize + 1] += 1;
        }
        for c in 1..layer_start.len() {
            layer_start[c] += layer_start[c - 1];
        }

        // Immediate sub-ideal CSR, bucketed from the links the BFS already
        // discovered (see enumerate_core) — no re-hashing, no row copies.
        let mut sub_off = vec![0usize; ni + 1];
        for &(child, _, _) in &links {
            sub_off[child as usize + 1] += 1;
        }
        for i in 1..sub_off.len() {
            sub_off[i] += sub_off[i - 1];
        }
        let mut cursor = sub_off.clone();
        let mut sub_list = vec![(0u32, 0u32); links.len()];
        for &(child, parent, v) in &links {
            let slot = cursor[child as usize];
            cursor[child as usize] += 1;
            sub_list[slot] = (parent, v);
        }

        Ok(IdealLattice { arena: rows, cards, layer_start, sub_off, sub_list, table, n })
    }

    /// The lattice of a *linearized* graph: exactly the `|order|+1`
    /// prefixes of a topological order (the DPL construction, §5.1.2 —
    /// adding the Hamiltonian path `order[0] → order[1] → …` as artificial
    /// edges leaves precisely these ideals). Built directly from the order
    /// in `O(n²/64)` — no BFS, no graph copy with linearization edges —
    /// and identical in content (rows, layers, sub-ideal links, interning)
    /// to `enumerate` on the edge-augmented graph.
    ///
    /// `order` must be a permutation of `0..n` that is topologically valid
    /// for whatever graph the caller runs its DP on (costs stay on the
    /// original edges; the lattice only restricts which sets are carved).
    pub fn from_prefixes(n: usize, order: &[NodeId]) -> IdealLattice {
        debug_assert_eq!(order.len(), n);
        let ni = n + 1;
        let mut rows = SetArena::with_row_capacity(n, ni);
        let mut table = InternTable::with_capacity(ni);
        let mut cards: Vec<u32> = Vec::with_capacity(ni);
        rows.push_empty();
        let (root, fresh) = table.intern_last(&mut rows);
        debug_assert!(fresh && root == 0);
        cards.push(0);
        let mut sub_list: Vec<(u32, u32)> = Vec::with_capacity(n);
        for (c, &v) in order.iter().enumerate() {
            let staged = rows.push_copy(c);
            rows.set_bit(staged, v);
            let (nid, fresh) = table.intern_last(&mut rows);
            debug_assert!(fresh && nid as usize == c + 1);
            cards.push(c as u32 + 1);
            // prefix c+1 has exactly one immediate sub-ideal: prefix c,
            // obtained by removing its unique maximal element order[c]
            sub_list.push((c as u32, v as u32));
        }
        let layer_start: Vec<usize> = (0..=ni).collect();
        let sub_off: Vec<usize> = (0..=ni).map(|i| i.saturating_sub(1)).collect();
        IdealLattice { arena: rows, cards, layer_start, sub_off, sub_list, table, n }
    }

    /// Count ideals without building the lattice structure (no sub-ideal
    /// links, no layer index — just the BFS with dedup). Used to report the
    /// "Ideals" column of Table 1 cheaply; returns `cap` if aborted.
    pub fn count(g: &OpGraph, cap: usize) -> usize {
        match enumerate_core(g, cap, false) {
            Ok((rows, _, _, _)) => rows.len(),
            Err(c) => c,
        }
    }

    pub fn len(&self) -> usize {
        self.arena.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arena.is_empty()
    }

    /// Id of the empty ideal (always 0).
    pub fn empty_id(&self) -> IdealId {
        0
    }

    /// Id of the full node set (always the last ideal).
    pub fn full_id(&self) -> IdealId {
        self.arena.len() - 1
    }

    /// Borrowed view of ideal `id`.
    #[inline]
    pub fn ideal(&self, id: IdealId) -> IdealRef<'_> {
        IdealRef { words: self.arena.row(id), capacity: self.n }
    }

    /// Owned copy of ideal `id` (cold paths / interop).
    pub fn ideal_bitset(&self, id: IdealId) -> BitSet {
        BitSet::from_words(self.n, self.arena.row(id))
    }

    /// Cached cardinality of ideal `id`.
    #[inline]
    pub fn card(&self, id: IdealId) -> usize {
        self.cards[id] as usize
    }

    /// Is node `v` in ideal `id`?
    #[inline]
    pub fn contains(&self, id: IdealId, v: usize) -> bool {
        self.arena.contains(id, v)
    }

    /// Number of cardinality layers (= max cardinality + 1).
    pub fn num_layers(&self) -> usize {
        self.layer_start.len() - 1
    }

    /// Ids of the ideals with cardinality `c`, as a contiguous range.
    pub fn layer(&self, c: usize) -> std::ops::Range<IdealId> {
        self.layer_start[c]..self.layer_start[c + 1]
    }

    /// Immediate sub-ideals of `id`: `(sub_id, removed_node)` pairs.
    #[inline]
    pub fn subs(&self, id: IdealId) -> &[(u32, u32)] {
        &self.sub_list[self.sub_off[id]..self.sub_off[id + 1]]
    }

    /// The contiguous set `I_a \ I_b` as an owned bitset (reconstruction
    /// paths).
    pub fn difference_bitset(&self, a: IdealId, b: IdealId) -> BitSet {
        let mut words = self.arena.row(a).to_vec();
        arena::andnot_into(&mut words, self.arena.row(b));
        BitSet::from_words(self.n, &words)
    }

    pub fn id_of(&self, set: &BitSet) -> Option<IdealId> {
        if set.capacity() != self.n {
            return None;
        }
        self.table.find(set.fast_hash(), set.words(), &self.arena).map(|s| s as usize)
    }
}

/// Check Definition 5.1 directly (used by tests/property checks).
pub fn is_ideal(g: &OpGraph, set: &BitSet) -> bool {
    g.edges().all(|(u, v)| !set.contains(v) || set.contains(u))
}

/// The pre-arena reference lattice: one heap `BitSet` per ideal, HashMap
/// interning, full rescan of all nodes per BFS step. Retained as the
/// executable specification the property tests compare the arena lattice
/// against (identical ideal set, identical sub-ideal links); never used on
/// hot paths.
pub struct NaiveLattice {
    /// Sorted by (cardinality, hash).
    pub ideals: Vec<BitSet>,
    /// `subs[i]` = (immediate sub-ideal id, removed node).
    pub subs: Vec<Vec<(IdealId, NodeId)>>,
}

/// Reference enumeration (the original algorithm). Same `cap` semantics as
/// [`IdealLattice::enumerate`].
pub fn enumerate_naive(g: &OpGraph, cap: usize) -> Result<NaiveLattice, usize> {
    use std::collections::HashMap;
    let n = g.n();
    let mut index: HashMap<BitSet, IdealId> = HashMap::new();
    let mut ideals: Vec<BitSet> = Vec::new();

    let empty = BitSet::new(n);
    index.insert(empty.clone(), 0);
    ideals.push(empty);

    let mut frontier: Vec<IdealId> = vec![0];
    while let Some(id) = frontier.pop() {
        let ideal = ideals[id].clone();
        for v in 0..n {
            if ideal.contains(v) {
                continue;
            }
            if g.preds[v].iter().all(|&u| ideal.contains(u)) {
                let mut bigger = ideal.clone();
                bigger.insert(v);
                if !index.contains_key(&bigger) {
                    let new_id = ideals.len();
                    if new_id >= cap {
                        return Err(new_id);
                    }
                    index.insert(bigger.clone(), new_id);
                    ideals.push(bigger);
                    frontier.push(new_id);
                }
            }
        }
    }

    let mut order: Vec<IdealId> = (0..ideals.len()).collect();
    order.sort_by_key(|&i| (ideals[i].len(), ideals[i].fast_hash()));
    let ideals: Vec<BitSet> = order.iter().map(|&i| ideals[i].clone()).collect();
    let mut index = HashMap::with_capacity(ideals.len());
    for (i, s) in ideals.iter().enumerate() {
        index.insert(s.clone(), i);
    }

    let mut subs: Vec<Vec<(IdealId, NodeId)>> = vec![Vec::new(); ideals.len()];
    for (id, ideal) in ideals.iter().enumerate() {
        for v in ideal.iter() {
            if g.succs[v].iter().all(|&w| !ideal.contains(w)) {
                let mut smaller = ideal.clone();
                smaller.remove(v);
                subs[id].push((index[&smaller], v));
            }
        }
    }

    Ok(NaiveLattice { ideals, subs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::test_graphs::*;
    use crate::graph::{Node, OpGraph};

    #[test]
    fn chain_has_n_plus_1_ideals() {
        let g = chain(7);
        let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
        assert_eq!(lat.len(), 8);
        // every ideal is a prefix
        for id in 0..lat.len() {
            let v: Vec<usize> = lat.ideal(id).iter().collect();
            assert_eq!(v, (0..v.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn antichain_has_2_pow_n_ideals() {
        let mut g = OpGraph::new();
        for i in 0..5 {
            g.add_node(Node::new(format!("a{i}")));
        }
        let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
        assert_eq!(lat.len(), 32);
    }

    #[test]
    fn diamond_ideal_count() {
        // Ideals of the diamond: {}, {0}, {0,1}, {0,2}, {0,1,2}, {0,1,2,3} = 6.
        let lat = IdealLattice::enumerate(&diamond(), usize::MAX).unwrap();
        assert_eq!(lat.len(), 6);
        for id in 0..lat.len() {
            assert!(is_ideal(&diamond(), &lat.ideal_bitset(id)));
        }
    }

    #[test]
    fn sorted_by_cardinality_and_bounds() {
        let lat = IdealLattice::enumerate(&diamond(), usize::MAX).unwrap();
        for id in 1..lat.len() {
            assert!(lat.card(id - 1) <= lat.card(id));
            assert_eq!(lat.card(id), lat.ideal(id).len());
        }
        assert!(lat.ideal(lat.empty_id()).is_empty());
        assert_eq!(lat.card(lat.full_id()), 4);
    }

    #[test]
    fn layers_partition_ids_by_cardinality() {
        let lat = IdealLattice::enumerate(&diamond(), usize::MAX).unwrap();
        assert_eq!(lat.num_layers(), 5); // cardinalities 0..=4
        let mut seen = 0;
        for c in 0..lat.num_layers() {
            for id in lat.layer(c) {
                assert_eq!(lat.card(id), c);
                seen += 1;
            }
        }
        assert_eq!(seen, lat.len());
        assert_eq!(lat.layer(0), 0..1);
    }

    #[test]
    fn immediate_subs_are_ideals_one_smaller() {
        let g = diamond();
        let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
        for id in 0..lat.len() {
            for &(sub, removed) in lat.subs(id) {
                let (sub, removed) = (sub as usize, removed as usize);
                assert_eq!(lat.card(sub) + 1, lat.card(id));
                assert!(lat.contains(id, removed));
                assert!(!lat.contains(sub, removed));
                assert!(is_ideal(&g, &lat.ideal_bitset(sub)));
            }
        }
        // full ideal of diamond has exactly one maximal element (node 3)
        assert_eq!(lat.subs(lat.full_id()).len(), 1);
    }

    #[test]
    fn id_of_and_difference() {
        let g = diamond();
        let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
        for id in 0..lat.len() {
            assert_eq!(lat.id_of(&lat.ideal_bitset(id)), Some(id));
        }
        assert_eq!(lat.id_of(&BitSet::from_iter(4, [1])), None); // not an ideal
        let full = lat.full_id();
        let empty = lat.empty_id();
        assert_eq!(lat.difference_bitset(full, empty), BitSet::full(4));
        assert!(lat.difference_bitset(empty, full).is_empty());
    }

    #[test]
    fn cap_aborts() {
        let mut g = OpGraph::new();
        for i in 0..20 {
            g.add_node(Node::new(format!("a{i}")));
        }
        assert!(IdealLattice::enumerate(&g, 1000).is_err());
        assert_eq!(IdealLattice::count(&g, 1000), 1000);
    }

    #[test]
    fn count_matches_enumerate() {
        for g in [diamond(), chain(9)] {
            let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
            assert_eq!(IdealLattice::count(&g, usize::MAX), lat.len());
        }
    }

    #[test]
    fn arena_lattice_matches_naive_reference() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xA7E4A);
        for case in 0..25 {
            let g = random_dag(&mut rng, 9, 0.3);
            let fast = IdealLattice::enumerate(&g, usize::MAX).unwrap();
            let naive = enumerate_naive(&g, usize::MAX).unwrap();
            assert_eq!(fast.len(), naive.ideals.len(), "case {case}");
            // identical ideal sets (order-insensitive)
            let mut a: Vec<Vec<usize>> =
                (0..fast.len()).map(|i| fast.ideal(i).iter().collect()).collect();
            let mut b: Vec<Vec<usize>> =
                naive.ideals.iter().map(|s| s.iter().collect()).collect();
            a.sort();
            b.sort();
            assert_eq!(a, b, "case {case}: ideal sets differ");
            // identical sub-ideal links, as (ideal set, removed node) pairs
            let mut la: Vec<(Vec<usize>, usize)> = Vec::new();
            for i in 0..fast.len() {
                for &(_, v) in fast.subs(i) {
                    la.push((fast.ideal(i).iter().collect(), v as usize));
                }
            }
            let mut lb: Vec<(Vec<usize>, usize)> = Vec::new();
            for (i, s) in naive.ideals.iter().enumerate() {
                for &(_, v) in &naive.subs[i] {
                    lb.push((s.iter().collect(), v));
                }
            }
            la.sort();
            lb.sort();
            assert_eq!(la, lb, "case {case}: sub-ideal links differ");
        }
    }

    #[test]
    fn from_prefixes_matches_enumerate_on_linearized_graph() {
        use crate::graph::topo;
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x11EA);
        for _ in 0..10 {
            let g = random_dag(&mut rng, 10, 0.3);
            let order = topo::dfs_linearization(&g);
            let lin = topo::add_linearization_edges(&g, &order);
            let via_enum = IdealLattice::enumerate(&lin, usize::MAX).unwrap();
            let direct = IdealLattice::from_prefixes(g.n(), &order);
            assert_eq!(direct.len(), via_enum.len());
            assert_eq!(direct.num_layers(), via_enum.num_layers());
            for id in 0..direct.len() {
                assert_eq!(
                    direct.ideal(id).iter().collect::<Vec<_>>(),
                    via_enum.ideal(id).iter().collect::<Vec<_>>(),
                    "row {id} differs"
                );
                assert_eq!(direct.card(id), via_enum.card(id));
                assert_eq!(direct.subs(id), via_enum.subs(id), "subs of {id} differ");
                assert_eq!(direct.id_of(&direct.ideal_bitset(id)), Some(id));
            }
        }
    }

    #[test]
    fn empty_graph_has_single_ideal() {
        let g = OpGraph::new();
        let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
        assert_eq!(lat.len(), 1);
        assert_eq!(lat.empty_id(), lat.full_id());
        assert!(lat.subs(0).is_empty());
    }
}

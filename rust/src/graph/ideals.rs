//! The ideal lattice of a DAG (paper §5.1.1).
//!
//! An *ideal* (Definition 5.1) is a downward-closed node set: if `(u,v) ∈ E`
//! and `v ∈ I` then `u ∈ I`. Ideals are exactly the possible "already
//! partitioned" prefixes of the throughput DP, and by Fact 5.2 every
//! contiguous set is a difference `I \ I'` of two nested ideals.
//!
//! [`IdealLattice`] enumerates all ideals (BFS over the lattice: extend an
//! ideal by any *minimal* element of its complement), assigns them dense
//! ids sorted by cardinality (so a DP can process them bottom-up), and
//! precomputes, for each ideal, the list of its *immediate* sub-ideals
//! (remove one maximal element). The DP walks arbitrary nested pairs
//! `I' ⊆ I` by exploring the lattice downward from `I` through these
//! immediate predecessors.

use super::{NodeId, OpGraph};
use crate::util::bitset::BitSet;
use std::collections::HashMap;

/// Dense id of an ideal within a lattice.
pub type IdealId = usize;

pub struct IdealLattice {
    /// All ideals, sorted by (cardinality, hash) — `ideals[0]` is ∅ and the
    /// last entry is the full node set.
    pub ideals: Vec<BitSet>,
    /// `subs[i]` = ids of ideals obtained from `ideals[i]` by removing one
    /// maximal element, together with the removed node.
    pub subs: Vec<Vec<(IdealId, NodeId)>>,
    /// Map from ideal bitset to id.
    index: HashMap<BitSet, IdealId>,
}

/// Hard cap to protect against graphs with exponentially many ideals
/// (e.g. wide antichains). Enumeration aborts with `Err(count_so_far)`.
pub const DEFAULT_IDEAL_CAP: usize = 2_000_000;

impl IdealLattice {
    /// Enumerate every ideal of `g`. Errors with the number seen so far if
    /// more than `cap` ideals exist — callers fall back to DPL (§5.1.2).
    pub fn enumerate(g: &OpGraph, cap: usize) -> Result<IdealLattice, usize> {
        let n = g.n();
        let mut index: HashMap<BitSet, IdealId> = HashMap::new();
        let mut ideals: Vec<BitSet> = Vec::new();

        let empty = BitSet::new(n);
        index.insert(empty.clone(), 0);
        ideals.push(empty);

        // BFS: grow each ideal by every addable node (all preds inside).
        let mut frontier: Vec<IdealId> = vec![0];
        while let Some(&id) = frontier.last() {
            frontier.pop();
            let ideal = ideals[id].clone();
            for v in 0..n {
                if ideal.contains(v) {
                    continue;
                }
                if g.preds[v].iter().all(|&u| ideal.contains(u)) {
                    let mut bigger = ideal.clone();
                    bigger.insert(v);
                    if !index.contains_key(&bigger) {
                        let new_id = ideals.len();
                        if new_id >= cap {
                            return Err(new_id);
                        }
                        index.insert(bigger.clone(), new_id);
                        ideals.push(bigger);
                        frontier.push(new_id);
                    }
                }
            }
        }

        // Sort by cardinality for bottom-up DP processing.
        let mut order: Vec<IdealId> = (0..ideals.len()).collect();
        order.sort_by_key(|&i| (ideals[i].len(), ideals[i].fast_hash()));
        let ideals: Vec<BitSet> = order.iter().map(|&i| ideals[i].clone()).collect();
        let mut index = HashMap::with_capacity(ideals.len());
        for (i, s) in ideals.iter().enumerate() {
            index.insert(s.clone(), i);
        }

        // Immediate sub-ideals: remove any maximal element (no successor
        // inside the ideal).
        let mut subs: Vec<Vec<(IdealId, NodeId)>> = vec![Vec::new(); ideals.len()];
        for (id, ideal) in ideals.iter().enumerate() {
            for v in ideal.iter() {
                if g.succs[v].iter().all(|&w| !ideal.contains(w)) {
                    let mut smaller = ideal.clone();
                    smaller.remove(v);
                    let sub_id = index[&smaller];
                    subs[id].push((sub_id, v));
                }
            }
        }

        Ok(IdealLattice { ideals, subs, index })
    }

    pub fn len(&self) -> usize {
        self.ideals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ideals.is_empty()
    }

    /// Id of the empty ideal (always 0 after sorting).
    pub fn empty_id(&self) -> IdealId {
        0
    }

    /// Id of the full node set (always the last ideal).
    pub fn full_id(&self) -> IdealId {
        self.ideals.len() - 1
    }

    pub fn id_of(&self, set: &BitSet) -> Option<IdealId> {
        self.index.get(set).copied()
    }

    /// Count ideals without materializing the lattice (used to report the
    /// "Ideals" column of Table 1 cheaply); returns `cap` if aborted.
    pub fn count(g: &OpGraph, cap: usize) -> usize {
        match Self::enumerate(g, cap) {
            Ok(l) => l.len(),
            Err(c) => c,
        }
    }
}

/// Check Definition 5.1 directly (used by tests/property checks).
pub fn is_ideal(g: &OpGraph, set: &BitSet) -> bool {
    g.edges().all(|(u, v)| !set.contains(v) || set.contains(u))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::test_graphs::*;
    use crate::graph::{Node, OpGraph};

    #[test]
    fn chain_has_n_plus_1_ideals() {
        let g = chain(7);
        let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
        assert_eq!(lat.len(), 8);
        // every ideal is a prefix
        for ideal in &lat.ideals {
            let v: Vec<usize> = ideal.iter().collect();
            assert_eq!(v, (0..v.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn antichain_has_2_pow_n_ideals() {
        let mut g = OpGraph::new();
        for i in 0..5 {
            g.add_node(Node::new(format!("a{i}")));
        }
        let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
        assert_eq!(lat.len(), 32);
    }

    #[test]
    fn diamond_ideal_count() {
        // Ideals of the diamond: {}, {0}, {0,1}, {0,2}, {0,1,2}, {0,1,2,3} = 6.
        let lat = IdealLattice::enumerate(&diamond(), usize::MAX).unwrap();
        assert_eq!(lat.len(), 6);
        for ideal in &lat.ideals {
            assert!(is_ideal(&diamond(), ideal));
        }
    }

    #[test]
    fn sorted_by_cardinality_and_bounds() {
        let lat = IdealLattice::enumerate(&diamond(), usize::MAX).unwrap();
        for w in lat.ideals.windows(2) {
            assert!(w[0].len() <= w[1].len());
        }
        assert!(lat.ideals[lat.empty_id()].is_empty());
        assert_eq!(lat.ideals[lat.full_id()].len(), 4);
    }

    #[test]
    fn immediate_subs_are_ideals_one_smaller() {
        let g = diamond();
        let lat = IdealLattice::enumerate(&g, usize::MAX).unwrap();
        for (id, subs) in lat.subs.iter().enumerate() {
            for &(sub, removed) in subs {
                assert_eq!(lat.ideals[sub].len() + 1, lat.ideals[id].len());
                assert!(lat.ideals[id].contains(removed));
                assert!(!lat.ideals[sub].contains(removed));
                assert!(is_ideal(&g, &lat.ideals[sub]));
            }
        }
        // full ideal of diamond has exactly one maximal element (node 3)
        assert_eq!(lat.subs[lat.full_id()].len(), 1);
    }

    #[test]
    fn cap_aborts() {
        let mut g = OpGraph::new();
        for i in 0..20 {
            g.add_node(Node::new(format!("a{i}")));
        }
        assert!(IdealLattice::enumerate(&g, 1000).is_err());
        assert_eq!(IdealLattice::count(&g, 1000), 1000);
    }
}

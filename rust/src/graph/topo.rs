//! Topological structure: toposort, reachability, DAG width (the paper's
//! antichain bound on CPU count, §4), and the DFS linearization used by the
//! DPL heuristic (§5.1.2).

use super::{NodeId, OpGraph};
use crate::util::arena::BitMatrix;
use crate::util::bitset::BitSet;

/// Kahn's algorithm. Returns `None` if the graph has a cycle (can happen
/// after colocation contraction, see `contract::contract_sccs`).
pub fn toposort(g: &OpGraph) -> Option<Vec<NodeId>> {
    let mut indeg: Vec<usize> = (0..g.n()).map(|v| g.preds[v].len()).collect();
    let mut queue: Vec<NodeId> = (0..g.n()).filter(|&v| indeg[v] == 0).collect();
    let mut order = Vec::with_capacity(g.n());
    let mut head = 0;
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        order.push(u);
        for &v in &g.succs[u] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(v);
            }
        }
    }
    (order.len() == g.n()).then_some(order)
}

/// True iff the graph is acyclic.
pub fn is_dag(g: &OpGraph) -> bool {
    toposort(g).is_some()
}

/// Full reachability as one flat [`BitMatrix`] (row u = descendants of u,
/// including u): a single allocation, cache-linear rows. Computed in
/// reverse topological order with word unions — `O(V·E/64)`.
pub fn reachability_matrix(g: &OpGraph) -> BitMatrix {
    crate::util::counters::bump_reachability();
    let order = toposort(g).expect("reachability requires a DAG");
    let mut m = BitMatrix::new(g.n());
    for &u in order.iter().rev() {
        m.set(u, u);
        for &v in &g.succs[u] {
            m.union_rows(u, v);
        }
    }
    m
}

/// Transpose reachability as a [`BitMatrix`]: row v = ancestors of v
/// (including v).
pub fn co_reachability_matrix(g: &OpGraph) -> BitMatrix {
    crate::util::counters::bump_co_reachability();
    let order = toposort(g).expect("co_reachability requires a DAG");
    let mut m = BitMatrix::new(g.n());
    for &v in order.iter() {
        m.set(v, v);
        for &u in &g.preds[v] {
            m.union_rows(v, u);
        }
    }
    m
}

/// Full reachability: `reach[u].contains(v)` ⇔ there is a directed path
/// u ⇝ v (including u = v). Owned-bitset view of
/// [`reachability_matrix`] for callers that want independent rows; hot
/// paths use the matrix directly.
pub fn reachability(g: &OpGraph) -> Vec<BitSet> {
    let m = reachability_matrix(g);
    (0..g.n()).map(|u| BitSet::from_words(g.n(), m.row(u))).collect()
}

/// Transpose reachability: `co_reach[v]` = all ancestors of v (including v).
pub fn co_reachability(g: &OpGraph) -> Vec<BitSet> {
    let m = co_reachability_matrix(g);
    (0..g.n()).map(|v| BitSet::from_words(g.n(), m.row(v))).collect()
}

/// Width of the DAG = size of the largest antichain = the paper's lower
/// bound on the CPU count `ℓ` for the latency IP (§4, footnote 3).
///
/// Computed via Mirsky/Dilworth-free greedy: by Dilworth's theorem the
/// width equals the minimum number of chains covering the DAG; we compute
/// the *maximum antichain* exactly with the standard reduction to maximum
/// bipartite matching on the transitive closure (König/Fulkerson).
pub fn width(g: &OpGraph) -> usize {
    let n = g.n();
    if n == 0 {
        return 0;
    }
    let reach = reachability_matrix(g);
    // Bipartite graph: left u — right v when u ⇝ v, u ≠ v. Minimum chain
    // cover = n - max_matching; width = min chain cover by Dilworth.
    let mut match_r: Vec<Option<usize>> = vec![None; n];
    let mut matching = 0;
    for u in 0..n {
        let mut visited = vec![false; n];
        if try_kuhn(u, &reach, &mut visited, &mut match_r) {
            matching += 1;
        }
    }
    n - matching
}

fn try_kuhn(
    u: usize,
    reach: &BitMatrix,
    visited: &mut [bool],
    match_r: &mut [Option<usize>],
) -> bool {
    for v in crate::util::arena::bits(reach.row(u)) {
        if v == u || visited[v] {
            continue;
        }
        visited[v] = true;
        if match_r[v].is_none() || try_kuhn(match_r[v].unwrap(), reach, visited, match_r) {
            match_r[v] = Some(u);
            return true;
        }
    }
    false
}

/// DFS-based linearization (§5.1.2): a topological order computed by a
/// depth-first post-order, which tends to keep branches of the DAG
/// together. Adding the path `order[0] -> order[1] -> …` as artificial
/// edges collapses the ideal lattice to `|V|+1` ideals — the DPL heuristic.
pub fn dfs_linearization(g: &OpGraph) -> Vec<NodeId> {
    let n = g.n();
    let mut state = vec![0u8; n]; // 0 unvisited, 1 on stack, 2 done
    let mut post = Vec::with_capacity(n);
    // Iterative DFS from every root (in-degree 0), then any leftovers.
    let roots: Vec<NodeId> =
        (0..n).filter(|&v| g.preds[v].is_empty()).chain(0..n).collect();
    for root in roots {
        if state[root] != 0 {
            continue;
        }
        let mut stack: Vec<(NodeId, usize)> = vec![(root, 0)];
        state[root] = 1;
        while let Some(top) = stack.last_mut() {
            let (u, ci) = (top.0, top.1);
            if ci < g.succs[u].len() {
                top.1 += 1;
                let v = g.succs[u][ci];
                if state[v] == 0 {
                    state[v] = 1;
                    stack.push((v, 0));
                }
            } else {
                state[u] = 2;
                post.push(u);
                stack.pop();
            }
        }
    }
    post.reverse(); // reverse post-order = topological order
    post
}

/// Add the artificial Hamiltonian path along `order` (used by DPL). Returns
/// a copy of the graph with the extra zero-cost precedence edges.
pub fn add_linearization_edges(g: &OpGraph, order: &[NodeId]) -> OpGraph {
    let mut out = g.clone();
    for w in order.windows(2) {
        out.add_edge(w[0], w[1]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::test_graphs::*;
    use crate::graph::Node;

    #[test]
    fn toposort_chain() {
        let g = chain(5);
        assert_eq!(toposort(&g).unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn toposort_respects_edges() {
        let g = diamond();
        let order = toposort(&g).unwrap();
        let pos: Vec<usize> =
            (0..4).map(|v| order.iter().position(|&x| x == v).unwrap()).collect();
        for (u, v) in g.edges() {
            assert!(pos[u] < pos[v]);
        }
    }

    #[test]
    fn detects_cycle() {
        let mut g = chain(3);
        g.add_edge(2, 0);
        assert!(toposort(&g).is_none());
        assert!(!is_dag(&g));
    }

    #[test]
    fn reachability_diamond() {
        let g = diamond();
        let r = reachability(&g);
        assert!(r[0].contains(3));
        assert!(r[0].contains(0));
        assert!(!r[1].contains(2));
        assert!(r[1].contains(3));
        let cr = co_reachability(&g);
        assert!(cr[3].contains(0));
        assert!(!cr[1].contains(2));
    }

    #[test]
    fn matrix_matches_bitset_reachability() {
        use crate::util::proptest::random_dag;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x70B0);
        for _ in 0..10 {
            let g = random_dag(&mut rng, 12, 0.3);
            let m = reachability_matrix(&g);
            let cm = co_reachability_matrix(&g);
            let r = reachability(&g);
            let cr = co_reachability(&g);
            for u in 0..g.n() {
                for v in 0..g.n() {
                    assert_eq!(m.get(u, v), r[u].contains(v));
                    assert_eq!(cm.get(u, v), cr[u].contains(v));
                    assert_eq!(m.get(u, v), cm.get(v, u));
                }
            }
        }
    }

    #[test]
    fn width_examples() {
        assert_eq!(width(&chain(6)), 1);
        assert_eq!(width(&diamond()), 2);
        // 4 isolated nodes: width 4
        let mut g = crate::graph::OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("i{i}")));
        }
        assert_eq!(width(&g), 4);
    }

    #[test]
    fn linearization_is_topological() {
        let g = diamond();
        let order = dfs_linearization(&g);
        assert_eq!(order.len(), 4);
        let pos: Vec<usize> =
            (0..4).map(|v| order.iter().position(|&x| x == v).unwrap()).collect();
        for (u, v) in g.edges() {
            assert!(pos[u] < pos[v], "{u}->{v} violated in {order:?}");
        }
        let lin = add_linearization_edges(&g, &order);
        assert!(is_dag(&lin));
        // the linearized graph has a Hamiltonian path → unique toposort
        assert_eq!(toposort(&lin).unwrap(), order);
    }
}

//! Contiguity (paper Definition 3.1 and Fact 5.2).
//!
//! A set `S` is *contiguous* when there is no triple `u ∈ S, v ∉ S, w ∈ S`
//! with `u ⇝ v ⇝ w`: execution of S can then be invoked as one
//! uninterrupted accelerator call (all inputs in, compute, all outputs out).

use super::{topo, OpGraph};
use crate::util::arena;
use crate::util::bitset::BitSet;

/// Direct check of Definition 3.1 via reachability. `O(V·E/64)` per call —
/// meant for validation and tests; the optimizers never need it on their
/// hot paths (they construct contiguous sets by Fact 5.2). Reachability
/// rows live in one flat [`arena::BitMatrix`] allocation.
pub fn is_contiguous(g: &OpGraph, set: &BitSet) -> bool {
    if set.is_empty() {
        return true;
    }
    is_contiguous_in(&topo::reachability_matrix(g), set)
}

/// [`is_contiguous`] against a caller-supplied reachability matrix — the
/// hot-path form used by the branch-and-bound polish loops, which evaluate
/// thousands of candidate sets against one precomputed matrix (rebuilding
/// the `O(V·E/64)` matrix per candidate dominated the polish cost).
pub fn is_contiguous_in(reach: &crate::util::arena::BitMatrix, set: &BitSet) -> bool {
    if set.is_empty() {
        return true;
    }
    // reachable_from_s = nodes v ∉ S reachable from S (candidates for the
    // middle of a violating triple). Then check whether any of them reaches
    // back into S.
    let mut outside_below = vec![0u64; reach.stride()];
    for u in set.iter() {
        arena::or_into(&mut outside_below, reach.row(u));
    }
    arena::andnot_into(&mut outside_below, set.words());
    for v in arena::bits(&outside_below) {
        // does v reach any w ∈ S? (v itself is not in S)
        if arena::intersects(reach.row(v), set.words()) {
            return false;
        }
    }
    true
}

/// Fact 5.2, "only if" direction: decompose a contiguous `S` into nested
/// ideals `(I, I')` with `S = I \ I'`. Returns `None` if `S` is not
/// contiguous. `I = {v : some node of S reachable from v}`, `I' = I \ S`.
pub fn to_ideal_pair(g: &OpGraph, set: &BitSet) -> Option<(BitSet, BitSet)> {
    let reach = topo::reachability_matrix(g);
    let mut i = BitSet::new(g.n());
    for v in 0..g.n() {
        if arena::intersects(reach.row(v), set.words()) {
            i.insert(v);
        }
    }
    let i_prime = i.difference(set);
    // verify both are ideals — exactly when S was contiguous
    if super::ideals::is_ideal(g, &i) && super::ideals::is_ideal(g, &i_prime) {
        Some((i, i_prime))
    } else {
        None
    }
}

/// Split an arbitrary (possibly non-contiguous) set into the minimum chain
/// of contiguous pieces ordered topologically — the "virtual devices" of
/// §5.2 / Fig. 5b. Greedy: walk nodes in topological order, start a new
/// piece whenever adding the node would break contiguity of the current
/// piece *given the nodes of S that are still to come*.
pub fn virtual_device_split(g: &OpGraph, set: &BitSet) -> Vec<BitSet> {
    if set.is_empty() {
        return Vec::new();
    }
    let order = topo::toposort(g).expect("DAG required");
    let reach = topo::reachability_matrix(g);
    virtual_device_split_in(g, &order, &reach, set)
}

/// [`virtual_device_split`] against a caller-supplied topological order
/// and reachability matrix — the hot-path form: the latency evaluator runs
/// once per IP leaf, and rebuilding the `O(V·E/64)` matrix per evaluation
/// dominated its cost (ROADMAP item (d) analogue; the throughput-side fix
/// is [`is_contiguous_in`]).
pub fn virtual_device_split_in(
    g: &OpGraph,
    order: &[usize],
    reach: &crate::util::arena::BitMatrix,
    set: &BitSet,
) -> Vec<BitSet> {
    if set.is_empty() {
        return Vec::new();
    }
    let members: Vec<usize> = order.iter().copied().filter(|&v| set.contains(v)).collect();

    let mut pieces: Vec<BitSet> = Vec::new();
    let mut current = BitSet::new(g.n());
    for &v in &members {
        // would `current + v` stay contiguous? it breaks iff some node u in
        // current reaches, through a vertex outside S∪current... simpler
        // exact check: u ∈ current, x ∉ current∪{v}, u ⇝ x ⇝ v.
        let mut trial = current.clone();
        trial.insert(v);
        let breaks = current.iter().any(|u| {
            // any intermediate x outside trial with u ⇝ x ⇝ v?
            arena::bits(reach.row(u))
                .any(|x| x != u && x != v && !trial.contains(x) && reach.get(x, v))
        });
        if breaks {
            pieces.push(current);
            current = BitSet::new(g.n());
        }
        current.insert(v);
    }
    if !current.is_empty() {
        pieces.push(current);
    }
    pieces
}

/// Shared inner loop of the branch-and-bound searches
/// (`algos::ip_throughput`, `algos::ip_latency`): would adding `v` to a
/// device currently holding `set` keep it contiguous, *given that nodes
/// are assigned in topological order* (so every violating middle vertex is
/// already assigned)? True iff no assigned non-member `x` satisfies
/// `set ⇝ x ⇝ v`. All arguments are word slices of one stride;
/// `scratch` is caller-provided so the check allocates nothing.
pub fn prefix_contiguity_ok(
    set_reach: &[u64],
    ancestors_of_v: &[u64],
    assigned: &[u64],
    set: &[u64],
    v: usize,
    scratch: &mut [u64],
) -> bool {
    scratch.copy_from_slice(set_reach);
    arena::and_into(scratch, ancestors_of_v);
    arena::and_into(scratch, assigned);
    arena::andnot_into(scratch, set);
    arena::word_clear(scratch, v);
    !arena::any(scratch)
}

/// Is the device-level condensation of a partition acyclic? This is the
/// *pipeline-orderable* property: exactly the partitions expressible as a
/// chain of ideals, i.e. the search space of the §5.1.1 DP. Note it is
/// strictly stronger than per-device contiguity (the Fig.-6 IP constraint
/// (16)): two contiguous sets can be mutually dependent through direct
/// edges, which the DP excludes but the IP admits (such splits are still
/// schedulable at max-load via the §5.2 virtual-device construction).
pub fn partition_pipeline_orderable(g: &OpGraph, device_of: &[usize], nd: usize) -> bool {
    // condensation: macro edge d1 -> d2 when some edge (u,v) has
    // device(u)=d1 != d2=device(v); check acyclicity via Kahn.
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nd];
    let mut indeg = vec![0usize; nd];
    let mut seen = std::collections::BTreeSet::new();
    for (u, v) in g.edges() {
        let (a, b) = (device_of[u], device_of[v]);
        if a != b && seen.insert((a, b)) {
            adj[a].push(b);
            indeg[b] += 1;
        }
    }
    let mut queue: Vec<usize> = (0..nd).filter(|&d| indeg[d] == 0).collect();
    let mut done = 0;
    while let Some(d) = queue.pop() {
        done += 1;
        for &e in &adj[d] {
            indeg[e] -= 1;
            if indeg[e] == 0 {
                queue.push(e);
            }
        }
    }
    done == nd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::test_graphs::*;
    use crate::graph::{ideals::is_ideal, Node, OpGraph};

    #[test]
    fn pipeline_orderable_vs_contiguous() {
        // a1 -> b1, b2 -> a2: A = {a1, a2}, B = {b1, b2} are each contiguous
        // but mutually dependent — contiguous yet NOT pipeline-orderable.
        let mut g = OpGraph::new();
        let a1 = g.add_node(Node::new("a1"));
        let a2 = g.add_node(Node::new("a2"));
        let b1 = g.add_node(Node::new("b1"));
        let b2 = g.add_node(Node::new("b2"));
        g.add_edge(a1, b1);
        g.add_edge(b2, a2);
        let assign = vec![0, 0, 1, 1];
        assert!(is_contiguous(&g, &BitSet::from_iter(4, [a1, a2])));
        assert!(is_contiguous(&g, &BitSet::from_iter(4, [b1, b2])));
        assert!(!partition_pipeline_orderable(&g, &assign, 2));
        // chain split is orderable
        let g2 = chain(4);
        assert!(partition_pipeline_orderable(&g2, &[0, 0, 1, 1], 2));
    }

    #[test]
    fn fig1_examples() {
        // Fig. 1a: in the diamond, {1, 2} is contiguous (parallel branches,
        // no path through the complement), and for a chain {0, 2} is not.
        assert!(is_contiguous(&diamond(), &BitSet::from_iter(4, [1, 2])));
        assert!(!is_contiguous(&chain(3), &BitSet::from_iter(3, [0, 2])));
    }

    #[test]
    fn empty_and_full_are_contiguous() {
        let g = diamond();
        assert!(is_contiguous(&g, &BitSet::new(4)));
        assert!(is_contiguous(&g, &BitSet::full(4)));
    }

    #[test]
    fn connected_but_not_contiguous() {
        // Fig. 1b flavor: 0->1->2, 0->3->2 ; S={0,1,2} is contiguous,
        // but in 0->1, 0->2, 1->3, 2->3 take S={0,1,3}: 0⇝2⇝3 with 2∉S.
        let mut g = OpGraph::new();
        for i in 0..4 {
            g.add_node(Node::new(format!("n{i}")));
        }
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        assert!(!is_contiguous(&g, &BitSet::from_iter(4, [0, 1, 3])));
    }

    #[test]
    fn fact_5_2_roundtrip() {
        let g = diamond();
        let s = BitSet::from_iter(4, [1, 2, 3]);
        assert!(is_contiguous(&g, &s));
        let (i, i_prime) = to_ideal_pair(&g, &s).unwrap();
        assert!(is_ideal(&g, &i));
        assert!(is_ideal(&g, &i_prime));
        assert!(i_prime.is_subset(&i));
        assert_eq!(i.difference(&i_prime), s);
    }

    #[test]
    fn fact_5_2_rejects_non_contiguous() {
        let g = chain(3);
        assert!(to_ideal_pair(&g, &BitSet::from_iter(3, [0, 2])).is_none());
    }

    #[test]
    fn virtual_devices_cover_and_are_contiguous() {
        let g = chain(5);
        let s = BitSet::from_iter(5, [0, 1, 3, 4]); // two runs
        let pieces = virtual_device_split(&g, &s);
        assert_eq!(pieces.len(), 2);
        let mut union = BitSet::new(5);
        for p in &pieces {
            assert!(is_contiguous(&g, p));
            union.union_with(p);
        }
        assert_eq!(union, s);
    }

    #[test]
    fn prefix_check_matches_direct_check_when_all_assigned() {
        let g = chain(4);
        let reach = topo::reachability_matrix(&g);
        let all = BitSet::full(4);
        let mut scratch = vec![0u64; reach.stride()];
        // device holds {0}; set_reach = reach(0)
        let set = BitSet::from_iter(4, [0]);
        // adding 1 keeps {0,1} contiguous; adding 2 skips over 1
        for (v, expect) in [(1, true), (2, false), (3, false)] {
            let got = prefix_contiguity_ok(
                reach.row(0),
                topo::co_reachability_matrix(&g).row(v),
                all.words(),
                set.words(),
                v,
                &mut scratch,
            );
            assert_eq!(got, expect, "v={v}");
            let mut trial = set.clone();
            trial.insert(v);
            assert_eq!(is_contiguous(&g, &trial), expect, "direct check v={v}");
        }
    }

    #[test]
    fn virtual_device_split_in_matches_owned_form() {
        let g = chain(6);
        let order = topo::toposort(&g).unwrap();
        let reach = topo::reachability_matrix(&g);
        let s = BitSet::from_iter(6, [0, 1, 3, 5]);
        assert_eq!(virtual_device_split(&g, &s), virtual_device_split_in(&g, &order, &reach, &s));
    }

    #[test]
    fn virtual_devices_single_piece_when_contiguous() {
        let g = diamond();
        let s = BitSet::from_iter(4, [1, 2]);
        assert_eq!(virtual_device_split(&g, &s).len(), 1);
    }
}

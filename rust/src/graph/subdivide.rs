//! Non-uniform outgoing-communication reduction (Appendix B).
//!
//! The model of §3 attaches the transfer cost to the *node* (`c_u`), but
//! ONNX-derived workloads attach it to edges, and a node may send different
//! outputs to different consumers. When a node's outgoing edges carry
//! different costs, we subdivide each edge `(u, v_j)` with a zero-cost node
//! `w_j` colocated with `u`, set `c_{w_j}` to the edge cost, and make `c_u`
//! unpayable (`u` is colocated with all its successors `w_j`, so its own
//! comm cost can never be charged).

use super::{Node, NodeId, OpGraph};

/// Outcome of the reduction: the rewritten graph plus, for each new node,
/// which original edge it represents (for mapping placements back).
pub struct Subdivision {
    pub graph: OpGraph,
    /// `origin[w] = Some((u, v))` when node `w` subdivides original edge
    /// `(u, v)`; `None` for original nodes.
    pub origin: Vec<Option<(NodeId, NodeId)>>,
}

/// Apply the App.-B reduction wherever a node has outgoing edges with
/// non-uniform costs. Nodes whose outgoing edge costs agree simply get that
/// cost as `c_u` (the common case). Edges with no recorded cost keep the
/// node's existing `comm`.
pub fn reduce_edge_costs(g: &OpGraph) -> Subdivision {
    let mut out = g.clone();
    out.edge_costs.clear();
    let mut origin: Vec<Option<(NodeId, NodeId)>> = vec![None; g.n()];

    // fresh color classes for the forced colocations
    let mut next_color =
        g.nodes.iter().filter_map(|n| n.color_class).max().map_or(0, |m| m + 1);

    for u in 0..g.n() {
        let costs: Vec<Option<f64>> =
            g.succs[u].iter().map(|&v| g.edge_costs.get(&(u, v)).copied()).collect();
        let known: Vec<f64> = costs.iter().filter_map(|c| *c).collect();
        if known.is_empty() {
            continue; // no per-edge costs: node comm already authoritative
        }
        let uniform = known.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12)
            && known.len() == costs.len();
        if uniform {
            out.nodes[u].comm = known[0];
            continue;
        }

        // Non-uniform: subdivide every outgoing edge of u.
        let succs = g.succs[u].clone();
        // ensure u has a color class to colocate the w_j with
        let color = *out.nodes[u].color_class.get_or_insert_with(|| {
            let c = next_color;
            next_color += 1;
            c
        });
        // detach u's outgoing edges
        for &v in &succs {
            out.succs[u].retain(|&w| w != v);
            out.preds[v].retain(|&w| w != u);
        }
        for &v in &succs {
            let cost = g.edge_costs.get(&(u, v)).copied().unwrap_or(g.nodes[u].comm);
            let mut w = Node::new(format!("{}_out{}", g.nodes[u].name, v));
            w.p_cpu = 0.0;
            w.p_acc = 0.0;
            w.mem = 0.0;
            w.comm = cost;
            w.color_class = Some(color);
            w.kind = g.nodes[u].kind;
            let wid = out.add_node(w);
            origin.push(Some((u, v)));
            out.add_edge(u, wid);
            out.add_edge(wid, v);
        }
        // u's own comm can never be charged (all successors colocated)
        out.nodes[u].comm = 0.0;
    }
    Subdivision { graph: out, origin }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::is_dag;

    #[test]
    fn uniform_costs_fold_into_node() {
        let mut g = OpGraph::new();
        for i in 0..3 {
            g.add_node(Node::new(format!("n{i}")).comm(9.0));
        }
        g.add_edge_cost(0, 1, 2.5);
        g.add_edge_cost(0, 2, 2.5);
        let s = reduce_edge_costs(&g);
        assert_eq!(s.graph.n(), 3);
        assert!((s.graph.nodes[0].comm - 2.5).abs() < 1e-12);
    }

    #[test]
    fn non_uniform_costs_subdivide() {
        let mut g = OpGraph::new();
        for i in 0..3 {
            g.add_node(Node::new(format!("n{i}")));
        }
        g.add_edge_cost(0, 1, 1.0);
        g.add_edge_cost(0, 2, 5.0);
        let s = reduce_edge_costs(&g);
        assert_eq!(s.graph.n(), 5);
        assert!(is_dag(&s.graph));
        // u's comm zeroed; w_j nodes carry the edge costs and share u's color
        assert_eq!(s.graph.nodes[0].comm, 0.0);
        let color = s.graph.nodes[0].color_class.unwrap();
        let new_nodes: Vec<usize> = (3..5).collect();
        let mut seen_costs: Vec<f64> =
            new_nodes.iter().map(|&w| s.graph.nodes[w].comm).collect();
        seen_costs.sort_by(f64::total_cmp);
        assert_eq!(seen_costs, vec![1.0, 5.0]);
        for &w in &new_nodes {
            assert_eq!(s.graph.nodes[w].color_class, Some(color));
            assert_eq!(s.origin[w].unwrap().0, 0);
            assert_eq!(s.graph.nodes[w].mem, 0.0);
        }
        // path structure preserved: 0 -> w -> v
        assert_eq!(s.graph.succs[0].len(), 2);
        for &w in &new_nodes {
            assert_eq!(s.graph.succs[w].len(), 1);
        }
    }

    #[test]
    fn no_edge_costs_is_noop() {
        let g = crate::graph::test_graphs::diamond();
        let s = reduce_edge_costs(&g);
        assert_eq!(s.graph.n(), g.n());
        assert_eq!(s.graph.num_edges(), g.num_edges());
    }
}

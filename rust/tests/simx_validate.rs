//! ISSUE-4 acceptance: `simx::validate` — every registry solver's
//! predicted objective matches simulated steady-state TPS on ≥ 2
//! heterogeneous fleets within the documented tolerance — and the
//! scripted device-loss loop demo shows the re-planned placement strictly
//! beating the degraded no-replan fallback.

use dnn_partition::baselines::expert::ExpertStyle;
use dnn_partition::coordinator::context::SolveOpts;
use dnn_partition::coordinator::placement::{
    AlgoChoice, Device, DeviceClass, Fleet, PlanRequest,
};
use dnn_partition::coordinator::planner::Algorithm;
use dnn_partition::graph::{Node, OpGraph};
use dnn_partition::runtime::server::ServingPlanner;
use dnn_partition::simx::engine::{Schedule, Stall};
use dnn_partition::simx::event::EventScript;
use dnn_partition::simx::loop_;
use dnn_partition::simx::validate::{self, DEFAULT_TOLERANCE};
use std::time::Duration;

fn chain(n: usize) -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..n {
        g.add_node(Node::new(format!("c{i}")).cpu(20.0).acc(1.0).mem(1.0).comm(0.05));
    }
    for i in 1..n {
        g.add_edge(i - 1, i);
    }
    g
}

fn training_chain(n: usize) -> OpGraph {
    dnn_partition::util::proptest::training_chain(
        n,
        &Node::new("f").cpu(20.0).acc(1.0).mem(1.0).comm(0.05),
        &Node::new("b").cpu(20.0).acc(1.5).mem(0.5).comm(0.05),
    )
}

fn opts() -> SolveOpts {
    SolveOpts {
        ip_budget: Duration::from_secs(10),
        gap_target: 0.0,
        expert: Some(ExpertStyle::EqualStripes),
        ..SolveOpts::default()
    }
}

/// Two acceptance fleets: heterogeneous in speed (and bandwidth), caps
/// left unlimited so even the memory-oblivious baselines stay simulable.
fn hetero_fleets() -> Vec<PlanRequest> {
    vec![
        PlanRequest::new(Fleet::new(vec![
            DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
            DeviceClass::acc("slow", 2, f64::INFINITY),
            DeviceClass::cpu("cpu", 1),
        ])),
        PlanRequest::new(
            Fleet::new(vec![
                DeviceClass::acc("a", 2, f64::INFINITY).speed(3.0),
                DeviceClass::acc("b", 1, f64::INFINITY).speed(1.5),
                DeviceClass::cpu("cpu", 1),
            ])
            .bandwidth(2.0),
        ),
    ]
}

#[test]
fn every_registry_solver_validates_on_heterogeneous_fleets() {
    let g = chain(10);
    for (fi, req) in hetero_fleets().into_iter().enumerate() {
        let report =
            validate::validate_request(&g, &req, &Algorithm::ALL, &opts(), 64, DEFAULT_TOLERANCE)
                .unwrap_or_else(|e| panic!("fleet {fi}: {e}"));
        assert!(
            report.skipped.is_empty(),
            "fleet {fi}: uncapped fleets must skip nothing, skipped {:?}",
            report.skipped
        );
        assert_eq!(report.rows.len(), Algorithm::ALL.len(), "fleet {fi}");
        assert!(
            report.all_within(),
            "fleet {fi}: worst row {:?} (max rel err {:.3}, tolerance {})",
            report.worst().map(|r| (r.algorithm, r.predicted, r.simulated)),
            report.max_rel_err(),
            report.tolerance
        );
        // the throughput solvers' own claimed objective is the predicted
        // max-load — spot-check the exact DP row
        let dp_row = report
            .rows
            .iter()
            .find(|r| r.algorithm == Algorithm::Dp)
            .expect("dp row");
        assert!(dp_row.predicted.is_finite() && dp_row.simulated.is_finite());
    }
}

#[test]
fn training_fleet_validates_under_1f1b() {
    let g = training_chain(6);
    let req = PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
        DeviceClass::acc("slow", 2, f64::INFINITY),
        DeviceClass::cpu("cpu", 1),
    ]));
    assert_eq!(validate::replay_schedule(&g, &req), Schedule::PipeDream1F1B);
    let algs = [Algorithm::Dp, Algorithm::PipeDream, Algorithm::Greedy];
    let report =
        validate::validate_request(&g, &req, &algs, &opts(), 48, DEFAULT_TOLERANCE).unwrap();
    assert_eq!(report.rows.len(), algs.len());
    assert!(
        report.all_within(),
        "worst {:?} rel {:.3}",
        report.worst().map(|r| r.algorithm),
        report.max_rel_err()
    );
}

#[test]
fn device_loss_replan_strictly_beats_cpu_failover() {
    let g = chain(10);
    let req = PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("fast", 2, f64::INFINITY).speed(2.0),
        DeviceClass::acc("slow", 2, f64::INFINITY),
        DeviceClass::cpu("cpu", 1),
    ]))
    .algorithm(AlgoChoice::Fixed(Algorithm::Dp));
    let script = EventScript::parse("fail:acc0@t=4").unwrap();
    let mut planner = ServingPlanner::new(Algorithm::Dp, opts());
    let demo = loop_::run_device_loss_demo(
        &g,
        &req,
        &script,
        Schedule::Pipelined,
        32,
        &mut planner,
    )
    .unwrap();
    // the engine saw the fault: the healthy plan strands samples
    assert!(matches!(demo.disrupted_stall, Some(Stall::DeviceLost { .. })));
    assert!(demo.disrupted_completed < demo.disrupted_injected);
    assert_eq!(demo.failed_device, Device::Acc(0));
    assert_eq!(demo.failed_class, "fast");
    // the acceptance inequality: re-planning strictly beats hot failover
    assert!(
        demo.replanned_tps < demo.degraded_tps,
        "replanned {} must beat degraded {}",
        demo.replanned_tps,
        demo.degraded_tps
    );
    assert!(demo.improvement() > 1.0);
    // a shrunk fleet can't beat the intact one
    assert!(demo.healthy_tps <= demo.replanned_tps + 1e-9);
    // the replan ran on the decremented fleet
    assert_eq!(demo.degraded_request.fleet.k(), req.fleet.k() - 1);
    demo.replanned
        .validate_req(&g, &demo.degraded_request)
        .unwrap();
    // the fallback is valid on the original fleet but pays CPU costs
    demo.degraded.validate_req(&g, &req).unwrap();
    assert!(demo.degraded_tps > demo.healthy_tps);
}

#[test]
fn replan_demo_requires_an_accelerator_fail_event() {
    let g = chain(6);
    let req = PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("acc", 2, f64::INFINITY),
        DeviceClass::cpu("cpu", 1),
    ]));
    let mut planner = ServingPlanner::new(Algorithm::Dp, opts());
    let no_fail = EventScript::parse("slow:acc0*0.5@t=2").unwrap();
    assert!(loop_::run_device_loss_demo(
        &g,
        &req,
        &no_fail,
        Schedule::Pipelined,
        8,
        &mut planner
    )
    .is_err());
    let cpu_fail = EventScript::parse("fail:cpu0@t=2").unwrap();
    assert!(loop_::run_device_loss_demo(
        &g,
        &req,
        &cpu_fail,
        Schedule::Pipelined,
        8,
        &mut planner
    )
    .is_err());
    let out_of_range = EventScript::parse("fail:acc7@t=2").unwrap();
    assert!(loop_::run_device_loss_demo(
        &g,
        &req,
        &out_of_range,
        Schedule::Pipelined,
        8,
        &mut planner
    )
    .is_err());
}

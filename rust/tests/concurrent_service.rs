//! PR-6 concurrency guarantees of
//! [`ConcurrentService`](dnn_partition::coordinator::concurrent::ConcurrentService):
//!
//! * N threads hammering one shared service through `&self` produce
//!   results **bitwise identical** to a sequential [`PlannerService`]
//!   drain, for every registered solver — sharing may never change a
//!   result, only its cost.
//! * Single-flight dedup: concurrent requests for one fingerprint build
//!   the [`ProblemCtx`] exactly once, observed through the process-wide
//!   [`counters::ctx_builds`] counter.
//!
//! The ctx-build counter is a process-wide atomic, so the tests that
//! assert on its delta serialize behind one mutex (other integration
//! tests in this *file* are the only other bumpers in the process — each
//! Rust test binary is its own process).

use dnn_partition::baselines::expert::ExpertStyle;
use dnn_partition::coordinator::concurrent::ConcurrentService;
use dnn_partition::coordinator::context::SolveOpts;
use dnn_partition::coordinator::placement::{AlgoChoice, Objective, PlanRequest, Scenario};
use dnn_partition::coordinator::planner::Algorithm;
use dnn_partition::coordinator::service::PlannerService;
use dnn_partition::util::counters;
use dnn_partition::util::proptest::random_dag;
use dnn_partition::util::rng::Rng;
use std::sync::Mutex;
use std::time::Duration;

/// Serializes every test in this file: the single-flight tests assert on
/// deltas of the process-wide ctx-build counter, so no other test here may
/// build contexts concurrently (cargo runs a binary's tests in parallel
/// threads of one process).
static CTX_COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn exact_opts() -> SolveOpts {
    SolveOpts {
        ip_budget: Duration::from_secs(10),
        // gap 0 ⇒ the IPs run to proven optimality on these small graphs,
        // so every solve — warm-started or not — returns the same optimum
        gap_target: 0.0,
        expert: Some(ExpertStyle::EqualStripes),
        ..SolveOpts::default()
    }
}

#[test]
fn hammering_matches_sequential_service_for_every_solver() {
    let _guard = CTX_COUNTER_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut rng = Rng::new(0xC0C0);
    let g = random_dag(&mut rng, 8, 0.3);
    let sc = Scenario::new(2, 1, f64::INFINITY);
    let opts = exact_opts();

    // sequential ground truth: one single-owner service, one pass
    let mut seq = PlannerService::new(4);
    let expected: Vec<_> = Algorithm::ALL
        .iter()
        .map(|&alg| seq.plan(&g, &sc, alg, &opts).unwrap())
        .collect();

    // concurrent: 4 threads × all 12 solvers against one shared service
    let svc = ConcurrentService::new(4, 8);
    let runs: Vec<Vec<_>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (svc, g, sc, opts) = (&svc, &g, &sc, &opts);
                scope.spawn(move || {
                    Algorithm::ALL
                        .iter()
                        .map(|&alg| svc.plan(g, sc, alg, opts).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    for (ti, results) in runs.iter().enumerate() {
        for (ai, (alg, r)) in Algorithm::ALL.iter().zip(results).enumerate() {
            assert_eq!(
                expected[ai].placement.assignment, r.placement.assignment,
                "thread {ti} {alg:?}: assignment diverged from the sequential service"
            );
            assert_eq!(
                expected[ai].placement.objective.to_bits(),
                r.placement.objective.to_bits(),
                "thread {ti} {alg:?}: objective not bitwise identical ({} vs {})",
                expected[ai].placement.objective,
                r.placement.objective
            );
        }
    }
    assert_eq!(svc.misses(), 1, "12 solvers × 4 threads share one context");
}

#[test]
fn hammered_plan_requests_match_sequential_for_ip_regimes() {
    // plan_request engages the incumbent cache; concurrent hammering must
    // still match the sequential drain bitwise, because exact_opts closes
    // these instances (a seed can then only reproduce the optimum, never
    // shift it — the warm-start monotonicity contract of DESIGN.md §8)
    let _guard = CTX_COUNTER_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut rng = Rng::new(0xD0D0);
    let g = random_dag(&mut rng, 8, 0.3);
    let opts = exact_opts();
    let reqs: Vec<PlanRequest> = vec![
        PlanRequest::new(dnn_partition::coordinator::placement::Fleet::uniform(
            2,
            1,
            f64::INFINITY,
        ))
        .objective(Objective::Throughput)
        .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous)),
        PlanRequest::new(dnn_partition::coordinator::placement::Fleet::uniform(
            2,
            1,
            f64::INFINITY,
        ))
        .objective(Objective::Throughput)
        .contiguous(false),
        PlanRequest::new(dnn_partition::coordinator::placement::Fleet::uniform(
            2,
            1,
            f64::INFINITY,
        ))
        .objective(Objective::Latency),
    ];

    let mut seq = PlannerService::new(4);
    let expected: Vec<_> =
        reqs.iter().map(|r| seq.plan_request(&g, r, &opts).unwrap()).collect();

    let svc = ConcurrentService::new(2, 8);
    let runs: Vec<Vec<_>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (svc, g, reqs, opts) = (&svc, &g, &reqs, &opts);
                scope.spawn(move || {
                    reqs.iter()
                        .map(|r| svc.plan_request(g, r, opts).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    for (ti, results) in runs.iter().enumerate() {
        for (ri, (exp, got)) in expected.iter().zip(results).enumerate() {
            assert_eq!(
                exp.placement.assignment, got.placement.assignment,
                "thread {ti} request {ri}: assignment diverged"
            );
            assert_eq!(
                exp.placement.objective.to_bits(),
                got.placement.objective.to_bits(),
                "thread {ti} request {ri}: objective not bitwise identical"
            );
        }
    }
}

#[test]
fn single_flight_builds_each_fingerprint_once() {
    let _guard = CTX_COUNTER_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut rng = Rng::new(0xF00D);
    let g = random_dag(&mut rng, 8, 0.3);
    let sc = Scenario::new(2, 1, f64::INFINITY);
    let svc = ConcurrentService::new(4, 8);

    let before = counters::ctx_builds();
    std::thread::scope(|scope| {
        for _ in 0..8 {
            let (svc, g, sc) = (&svc, &g, &sc);
            scope.spawn(move || svc.context(g, sc));
        }
    });
    let built = counters::ctx_builds() - before;
    assert_eq!(built, 1, "8 concurrent requests must build the context once");
    assert_eq!(svc.misses(), 1);
    assert_eq!(
        svc.hits() + svc.dedup_waits(),
        7,
        "the other 7 must hit the LRU or adopt the in-flight build"
    );
}

#[test]
fn single_flight_builds_once_per_distinct_fingerprint() {
    let _guard = CTX_COUNTER_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut rng = Rng::new(0xBEEF);
    let g = random_dag(&mut rng, 8, 0.3);
    let scenarios: Vec<Scenario> = (1..=3)
        .map(|k| Scenario::new(k, 1, f64::INFINITY))
        .collect();
    let svc = ConcurrentService::new(4, 8);

    let before = counters::ctx_builds();
    std::thread::scope(|scope| {
        for t in 0..9 {
            let (svc, g, scenarios) = (&svc, &g, &scenarios);
            scope.spawn(move || {
                // each scenario is requested by 3 threads concurrently
                svc.context(g, &scenarios[t % scenarios.len()])
            });
        }
    });
    let built = counters::ctx_builds() - before;
    assert_eq!(
        built,
        scenarios.len() as u64,
        "exactly one build per distinct fingerprint"
    );
    assert_eq!(svc.misses(), scenarios.len());
}

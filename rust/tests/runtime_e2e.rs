//! End-to-end runtime tests against the AOT artifacts. These SKIP (pass
//! vacuously, with a note) when `make artifacts` has not been run — cargo
//! test must work in a fresh checkout — and fully verify the
//! Rust-loads-JAX-HLO path when artifacts exist.

use dnn_partition::runtime::server::{self, Request, ServerConfig};
use dnn_partition::runtime::stage::{artifacts_dir, StageSpec};
use dnn_partition::util::json::Json;
use std::time::{Duration, Instant};

fn manifest() -> Option<(Json, std::path::PathBuf)> {
    let dir = artifacts_dir();
    let text = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
    Some((Json::parse(&text).ok()?, dir))
}

#[test]
fn stage_artifacts_compile_and_execute() {
    let Some((m, dir)) = manifest() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let batch = m.get("batch").as_usize().unwrap();
    let seq = m.get("seq").as_usize().unwrap();
    let hidden = m.get("hidden").as_usize().unwrap();
    let vocab = m.get("vocab").as_usize().unwrap();
    let stages = m.get("stages").as_arr().unwrap();
    let mut x = vec![0.1f32; batch * seq * hidden];
    for (i, s) in stages.iter().enumerate() {
        let spec = StageSpec {
            name: format!("s{i}"),
            path: dir.join(s.get("path").as_str().unwrap()),
            tuple_arity: 1,
            sample_shape: vec![seq, hidden],
        };
        let stage = spec.compile().expect("compile");
        let outs = stage.run_f32(&[(&x, &[batch, seq, hidden][..])]).expect("exec");
        x = outs.into_iter().next().unwrap();
        let expect_feat =
            if i + 1 == stages.len() { vocab } else { hidden };
        assert_eq!(x.len(), batch * seq * expect_feat, "stage {i} output size");
        assert!(x.iter().all(|v| v.is_finite()), "stage {i} produced non-finite values");
    }
}

#[test]
fn full_model_artifact_matches_stage_composition() {
    let Some((m, dir)) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let batch = m.get("batch").as_usize().unwrap();
    let seq = m.get("seq").as_usize().unwrap();
    let hidden = m.get("hidden").as_usize().unwrap();
    let shape = [batch, seq, hidden];
    let input: Vec<f32> = (0..batch * seq * hidden).map(|i| ((i % 17) as f32 - 8.0) / 10.0).collect();

    // staged
    let mut x = input.clone();
    for (i, s) in m.get("stages").as_arr().unwrap().iter().enumerate() {
        let spec = StageSpec {
            name: format!("s{i}"),
            path: dir.join(s.get("path").as_str().unwrap()),
            tuple_arity: 1,
            sample_shape: vec![seq, hidden],
        };
        let stage = spec.compile().unwrap();
        x = stage.run_f32(&[(&x, &shape[..])]).unwrap().into_iter().next().unwrap();
    }
    // monolithic
    let full = StageSpec {
        name: "full".into(),
        path: dir.join(m.get("full").as_str().unwrap()),
        tuple_arity: 1,
        sample_shape: vec![seq, hidden],
    }
    .compile()
    .unwrap();
    let y = full.run_f32(&[(&input, &shape[..])]).unwrap().into_iter().next().unwrap();
    assert_eq!(x.len(), y.len());
    for (i, (a, b)) in x.iter().zip(&y).enumerate() {
        assert!((a - b).abs() < 1e-4 + 1e-4 * b.abs(), "elem {i}: staged {a} vs full {b}");
    }
}

#[test]
fn threaded_pipeline_serves_all_requests() {
    let Some((m, dir)) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let batch = m.get("batch").as_usize().unwrap();
    let seq = m.get("seq").as_usize().unwrap();
    let hidden = m.get("hidden").as_usize().unwrap();
    let per_sample = seq * hidden;
    let specs: Vec<StageSpec> = m
        .get("stages")
        .as_arr()
        .unwrap()
        .iter()
        .enumerate()
        .map(|(i, s)| StageSpec {
            name: format!("s{i}"),
            path: dir.join(s.get("path").as_str().unwrap()),
            tuple_arity: 1,
            sample_shape: vec![seq, hidden],
        })
        .collect();
    let n = batch * 4;
    let requests: Vec<Request> = (0..n)
        .map(|i| Request { id: i as u64, data: vec![0.01; per_sample], enqueued: Instant::now() })
        .collect();
    let cfg = ServerConfig {
        max_batch: batch,
        batch_timeout: Duration::from_secs(5),
        input_elems: per_sample,
        queue_depth: 2,
    };
    let metrics = server::serve(requests, server::stage_factories(specs), &cfg);
    assert_eq!(metrics.completed, n);
    assert!(metrics.percentile(0.5) > 0.0);
}

//! Grammar hardening (PR-10 satellite): every user-facing parser —
//! [`Fleet::parse`], [`TopoSpec::parse`], [`EventScript::parse`],
//! [`Json::parse`] and the workload-JSON loader — must return `Err` on
//! malformed input, never panic, hang, or allocate absurdly. These
//! grammars are fed directly from CLI flags and on-disk files, so a
//! malformed byte string is normal operation, not an edge case.
//!
//! Two corpora per grammar, both seeded and deterministic:
//! * arbitrary byte strings (UTF-8-lossied), which exercise the lexer
//!   paths, and
//! * random mutations of *valid* strings, which get much deeper into the
//!   grammar than noise ever would.
//!
//! Every probe runs under `catch_unwind`; the assertion is only "no
//! panic" — whether the parse succeeds is the grammar's business.

use dnn_partition::coordinator::placement::Fleet;
use dnn_partition::simx::event::EventScript;
use dnn_partition::topo::TopoSpec;
use dnn_partition::util::json::Json;
use dnn_partition::util::rng::Rng;
use dnn_partition::workloads::{self, json as wjson};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Bytes that appear in the grammars under test, so random edits stay in
/// the neighborhood of parseable input instead of failing at the first
/// character.
const GRAMMAR_BYTES: &[u8] = b"0123456789xXaccpufstlow@:/.,|;+-=*_\"{}[]einrghbwkmd ";

fn arbitrary_bytes(rng: &mut Rng, max_len: usize) -> String {
    let len = rng.gen_range(max_len + 1);
    let bytes: Vec<u8> = (0..len).map(|_| rng.gen_range(256) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// One random edit: delete, insert, replace, truncate, or swap.
fn mutate(rng: &mut Rng, s: &str) -> String {
    let mut b: Vec<u8> = s.as_bytes().to_vec();
    if b.is_empty() {
        return String::from_utf8_lossy(&[*rng.choose(GRAMMAR_BYTES)]).into_owned();
    }
    let pos = rng.gen_range(b.len());
    match rng.gen_range(5) {
        0 => {
            b.remove(pos);
        }
        1 => b.insert(pos, *rng.choose(GRAMMAR_BYTES)),
        2 => b[pos] = *rng.choose(GRAMMAR_BYTES),
        3 => b.truncate(pos),
        _ => {
            let pos2 = rng.gen_range(b.len());
            b.swap(pos, pos2);
        }
    }
    String::from_utf8_lossy(&b).into_owned()
}

/// Assert that `parse(input)` returns (Ok or Err) without panicking.
fn assert_no_panic(what: &str, input: &str, parse: impl Fn(&str)) {
    let shown: String = input.chars().take(120).collect();
    assert!(
        catch_unwind(AssertUnwindSafe(|| parse(input))).is_ok(),
        "{what} panicked on input: {shown:?}"
    );
}

const VALID_FLEETS: &[&str] = &[
    "2xfast@2:32768,4xslow:16384,1xcpu",
    "8xacc:32768,1xcpu,topo=islands:2x4@900/64",
    "2xacc,bw=5",
    "1xslot+acc,1xslot2+cpu",
    "3xgpu@1.5:1024,topo=tiered:2x2x2@900/64/8",
    "2xacc,topo=matrix:0;5/5;0",
    "4xacc,1xcpu,topo=islands:0.2|1.3@900/64",
];

const VALID_TOPOS: &[&str] = &[
    "uniform:900",
    "islands:2x4@900/64",
    "islands:0.2|1.3@900/64",
    "tiered:2x2x2@900/64/8",
    "matrix:0;5/5;0",
    "matrix:0;5/5;0+0;1/1;0",
];

const VALID_EVENTS: &[&str] = &[
    "fail:acc0@t=5,slow:acc1*0.5@t=9,spike:+8@t=12",
    "fail:acc0@t=5,recover:acc0@t=12",
    "slow:cpu0*0.25@t=3",
    "spike:+16@t=1",
];

#[test]
fn fleet_parse_never_panics() {
    let mut rng = Rng::new(0xF1EE7);
    for _ in 0..1500 {
        let s = arbitrary_bytes(&mut rng, 64);
        assert_no_panic("Fleet::parse", &s, |s| {
            let _ = Fleet::parse(s);
        });
    }
    for _ in 0..1500 {
        let mut s = rng.choose(VALID_FLEETS).to_string();
        for _ in 0..=rng.gen_range(4) {
            s = mutate(&mut rng, &s);
        }
        assert_no_panic("Fleet::parse", &s, |s| {
            let _ = Fleet::parse(s);
        });
    }
}

#[test]
fn topo_parse_never_panics() {
    let mut rng = Rng::new(0x7090);
    for _ in 0..1500 {
        let s = arbitrary_bytes(&mut rng, 64);
        assert_no_panic("TopoSpec::parse", &s, |s| {
            let _ = TopoSpec::parse(s);
        });
    }
    for _ in 0..1500 {
        let mut s = rng.choose(VALID_TOPOS).to_string();
        for _ in 0..=rng.gen_range(4) {
            s = mutate(&mut rng, &s);
        }
        assert_no_panic("TopoSpec::parse", &s, |s| {
            let _ = TopoSpec::parse(s);
        });
    }
}

#[test]
fn fuzzed_slot_counts_error_instead_of_allocating() {
    // the shapes a fuzzer finds first: counts that would materialize
    // absurd per-slot (or n²) state if parsed literally
    for s in [
        "islands:999999999x999999999@900/64",
        "islands:18446744073709551615x2@900/64",
        "tiered:999999x999999x999999@900/64/8",
    ] {
        assert!(TopoSpec::parse(s).is_err(), "{s} must be rejected");
    }
    assert!(Fleet::parse("999999999xacc,topo=uniform:900").is_err());
    assert!(Fleet::parse("99999999999999999999xacc").is_err(), "count overflow");
}

#[test]
fn event_script_parse_never_panics() {
    let mut rng = Rng::new(0xE5E27);
    for _ in 0..1500 {
        let s = arbitrary_bytes(&mut rng, 64);
        assert_no_panic("EventScript::parse", &s, |s| {
            let _ = EventScript::parse(s);
        });
    }
    for _ in 0..1500 {
        let mut s = rng.choose(VALID_EVENTS).to_string();
        for _ in 0..=rng.gen_range(4) {
            s = mutate(&mut rng, &s);
        }
        assert_no_panic("EventScript::parse", &s, |s| {
            let _ = EventScript::parse(s);
        });
    }
}

#[test]
fn workload_json_loader_never_panics() {
    let mut rng = Rng::new(0x15011);
    // the real paper-format serialization of a real workload is the
    // mutation seed — mutations land inside the schema, not just the lexer
    let w = &workloads::table1_workloads()[0];
    let valid = wjson::to_json(w).to_string();
    let load = |text: &str| {
        if let Ok(j) = Json::parse(text) {
            let _ = wjson::from_json_workload(&j);
            let _ = wjson::from_json(&j);
        }
    };
    for _ in 0..400 {
        let s = arbitrary_bytes(&mut rng, 128);
        assert_no_panic("workload JSON loader", &s, load);
    }
    for _ in 0..400 {
        let mut s = valid.clone();
        for _ in 0..=rng.gen_range(6) {
            s = mutate(&mut rng, &s);
        }
        assert_no_panic("workload JSON loader", &s, load);
    }
}

#[test]
fn json_parse_never_panics_and_bounds_recursion() {
    let mut rng = Rng::new(0x150F2);
    for _ in 0..2000 {
        let s = arbitrary_bytes(&mut rng, 96);
        assert_no_panic("Json::parse", &s, |s| {
            let _ = Json::parse(s);
        });
    }
    // the classic parser-killer: unbounded nesting must be an Err, not a
    // stack overflow (which aborts the process — catch_unwind can't see it)
    let bomb = "[".repeat(1_000_000);
    assert!(Json::parse(&bomb).is_err());
    let obj_bomb = "{\"a\":".repeat(1_000_000);
    assert!(Json::parse(&obj_bomb).is_err());
}

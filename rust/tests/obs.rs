//! PR-9 observability guarantees: the log2 histogram's bucket layout and
//! merge algebra, the recorder's flush contract across `util::par` scoped
//! workers, the disabled recorder's no-op promise, and — the load-bearing
//! one — that recording is *bitwise invisible* to every registered
//! solver's results.
//!
//! The recorder is process-global and `cargo test` runs tests on parallel
//! threads, so every test that touches `set_enabled` serializes on
//! [`OBS_LOCK`], uses unique span/counter names, and asserts deltas
//! rather than absolute registry values.

use dnn_partition::baselines::expert::ExpertStyle;
use dnn_partition::coordinator::context::{ProblemCtx, SolveOpts, Solver};
use dnn_partition::coordinator::placement::Scenario;
use dnn_partition::coordinator::planner::Algorithm;
use dnn_partition::obs;
use dnn_partition::obs::hist::{bucket_lower, bucket_upper, BUCKETS};
use dnn_partition::obs::Histogram;
use dnn_partition::util::proptest::random_dag;
use dnn_partition::util::rng::Rng;
use std::sync::Mutex;
use std::time::Duration;

/// Serializes tests that flip the global `set_enabled` flag.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_lock() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[test]
fn histogram_bucket_boundaries() {
    let mut h = Histogram::new();
    // degenerate samples all land in the underflow bucket
    for v in [f64::NAN, -3.0, 0.0, 1e-300] {
        h.record(v);
    }
    assert_eq!(h.bucket_count(0), 4);
    // a bucket's inclusive lower bound stays inside it; its exclusive
    // upper bound is the next bucket's lower bound
    let mut h = Histogram::new();
    for i in 1..BUCKETS - 1 {
        h.record(bucket_lower(i));
    }
    for i in 1..BUCKETS - 1 {
        assert_eq!(h.bucket_count(i), 1, "lower bound of bucket {i} must stay in it");
        assert_eq!(bucket_upper(i), bucket_lower(i + 1), "buckets must tile the range");
    }
    // +inf overflows; the overflow bucket still feeds count/min/max
    let mut h = Histogram::new();
    h.record(f64::INFINITY);
    assert_eq!(h.bucket_count(BUCKETS - 1), 1);
    assert_eq!(h.count(), 1);
}

#[test]
fn histogram_merge_is_associative() {
    // samples are small integers and powers of two, so the f64 sums are
    // exact and merge order cannot perturb them — `PartialEq` compares
    // counts, sum, min, and max bitwise-equal here
    let mut parts = Vec::new();
    for (lo, hi) in [(1u64, 40), (41, 90), (91, 200)] {
        let mut h = Histogram::new();
        for v in lo..=hi {
            h.record(v as f64);
        }
        parts.push(h);
    }
    // (a ⊕ b) ⊕ c
    let mut left = parts[0].clone();
    left.merge(&parts[1]);
    left.merge(&parts[2]);
    // a ⊕ (b ⊕ c)
    let mut bc = parts[1].clone();
    bc.merge(&parts[2]);
    let mut right = parts[0].clone();
    right.merge(&bc);
    assert_eq!(left, right, "merge must be associative");
    // and both equal recording every sample into one histogram
    let mut direct = Histogram::new();
    for v in 1..=200u64 {
        direct.record(v as f64);
    }
    assert_eq!(left, direct, "merge must equal direct recording");
    assert_eq!(left.count(), 200);
    assert_eq!(left.sum(), (1..=200u64).sum::<u64>() as f64);
}

#[test]
fn spans_nest_across_par_worker_threads() {
    let _guard = obs_lock();
    obs::set_enabled(true);
    let mut states: Vec<usize> = (0..3).collect();
    dnn_partition::util::par::run_workers(&mut states, |t, _s| {
        let _outer = obs::span_cat(&format!("obs_test_outer_{t}"), "obs_test");
        let _inner = obs::span_cat(&format!("obs_test_inner_{t}"), "obs_test");
    });
    obs::set_enabled(false);
    // worker threads exited inside run_workers, so their thread-local
    // buffers have flushed: all six spans must already be visible here
    let snap = obs::snapshot();
    let find = |name: &str| {
        snap.spans
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("span {name} missing from snapshot"))
    };
    let mut tids = Vec::new();
    for t in 0..3 {
        let outer = find(&format!("obs_test_outer_{t}"));
        let inner = find(&format!("obs_test_inner_{t}"));
        assert_eq!(inner.tid, outer.tid, "worker {t}: nested spans share a lane");
        assert_eq!(
            inner.depth,
            outer.depth + 1,
            "worker {t}: inner span must nest one level deeper"
        );
        assert!(
            inner.ts_us >= outer.ts_us
                && inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1.0,
            "worker {t}: inner span must sit inside its parent's interval"
        );
        tids.push(outer.tid);
    }
    tids.sort_unstable();
    tids.dedup();
    assert_eq!(tids.len(), 3, "three scoped workers must land on three distinct lanes");
    // every recording thread has a registered name
    for &tid in &tids {
        assert!(
            snap.threads.iter().any(|(t, _)| *t == tid),
            "tid {tid} missing from thread registry"
        );
    }
}

#[test]
fn disabled_recorder_records_no_spans() {
    let _guard = obs_lock();
    obs::set_enabled(false);
    {
        let _span = obs::span("obs_test_disabled_span").arg(
            "ignored",
            dnn_partition::util::json::Json::Bool(true),
        );
        obs::instant("obs_test_disabled_instant", "obs_test", Vec::new());
    }
    obs::flush_thread();
    let snap = obs::snapshot();
    assert!(
        !snap.spans.iter().any(|s| s.name.starts_with("obs_test_disabled")),
        "a disabled recorder must not collect spans or instants"
    );
    // counters stay live regardless of the span switch
    let before = obs::counter("obs_test_disabled_total").get();
    obs::counter("obs_test_disabled_total").inc();
    assert_eq!(obs::counter("obs_test_disabled_total").get(), before + 1);
}

fn exact_opts() -> SolveOpts {
    SolveOpts {
        ip_budget: Duration::from_secs(10),
        // gap 0 ⇒ the IPs run to proven optimality on these small graphs,
        // so results depend only on the search — not on where a budget cut
        // happens to land
        gap_target: 0.0,
        expert: Some(ExpertStyle::EqualStripes),
        ..SolveOpts::default()
    }
}

#[test]
fn every_solver_bitwise_identical_recording_on_vs_off() {
    let _guard = obs_lock();
    let mut rng = Rng::new(0x0B5);
    let opts = exact_opts();
    for case in 0..2 {
        let g = random_dag(&mut rng, 8, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        for alg in Algorithm::ALL {
            obs::set_enabled(false);
            let off_ctx = ProblemCtx::new(g.clone(), sc.clone());
            let off = alg
                .solver()
                .solve(&off_ctx, &opts)
                .unwrap_or_else(|e| panic!("case {case} {alg:?} recording off: {e}"));
            obs::set_enabled(true);
            let on_ctx = ProblemCtx::new(g.clone(), sc.clone());
            let on = alg
                .solver()
                .solve(&on_ctx, &opts)
                .unwrap_or_else(|e| panic!("case {case} {alg:?} recording on: {e}"));
            obs::set_enabled(false);
            assert_eq!(
                off.placement.assignment, on.placement.assignment,
                "case {case} {alg:?}: recording changed the assignment"
            );
            assert_eq!(
                off.placement.objective.to_bits(),
                on.placement.objective.to_bits(),
                "case {case} {alg:?}: objective not bitwise identical ({} vs {})",
                off.placement.objective,
                on.placement.objective
            );
        }
    }
    // drop the spans the recorded solves accumulated so later profiling
    // phases (and other snapshots) start from a clean event log
    obs::reset_events();
}

//! ISSUE-3 acceptance tests for the heterogeneous-fleet `PlanRequest` API.
//!
//! 1. **Uniform-fleet equivalence**: every registry solver is *bitwise*
//!    identical planning under `Scenario::new(k, ℓ, M)` vs the equivalent
//!    hand-built one-accelerator-class `Fleet` — the legacy path has zero
//!    behavior change.
//! 2. **Heterogeneous end-to-end**: a two-accelerator-class fleet with
//!    different speeds AND different memory caps runs through `dp`, `ip`
//!    and `pipedream`, producing placements that validate per-class
//!    memory.

use dnn_partition::baselines::expert::ExpertStyle;
use dnn_partition::coordinator::context::{ProblemCtx, SolveOpts, Solver};
use dnn_partition::coordinator::placement::{
    AlgoChoice, Device, DeviceClass, Fleet, Objective, PlanRequest, Scenario,
};
use dnn_partition::coordinator::planner::{self, Algorithm};
use dnn_partition::coordinator::service::PlannerService;
use dnn_partition::graph::{Node, OpGraph};
use dnn_partition::util::proptest::random_dag;
use dnn_partition::util::rng::Rng;
use std::time::Duration;

fn exact_opts() -> SolveOpts {
    SolveOpts {
        ip_budget: Duration::from_secs(10),
        // gap 0 ⇒ the IPs run to proven optimality on these small graphs,
        // making their output deterministic
        gap_target: 0.0,
        expert: Some(ExpertStyle::EqualStripes),
        ..SolveOpts::default()
    }
}

/// The equivalent one-accelerator-class fleet request of a scenario,
/// built by hand (NOT via `Scenario::to_request`) so the test actually
/// exercises the fleet constructor path.
fn uniform_request(k: usize, l: usize, mem_cap: f64) -> PlanRequest {
    PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("acc", k, mem_cap),
        DeviceClass::cpu("cpu", l),
    ]))
}

#[test]
fn every_registry_solver_bitwise_identical_scenario_vs_uniform_fleet() {
    let mut rng = Rng::new(0xF1EE7);
    let opts = exact_opts();
    for case in 0..3 {
        let g = random_dag(&mut rng, 8, 0.3);
        // infinite cap: keeps every solver (incl. the hierarchy's fixed
        // 2-cluster default) feasible on random graphs; finite per-class
        // caps are exercised by the heterogeneous tests below
        let (k, l, mem_cap) = (2usize, 1usize, f64::INFINITY);
        let sc = Scenario::new(k, l, mem_cap);
        let req = uniform_request(k, l, mem_cap);
        for alg in Algorithm::ALL {
            let legacy_ctx = ProblemCtx::new(g.clone(), sc.clone());
            let legacy = alg
                .solver()
                .solve(&legacy_ctx, &opts)
                .unwrap_or_else(|e| panic!("case {case} {alg:?} scenario path: {e}"));
            let fleet_ctx = ProblemCtx::from_request(g.clone(), req.clone());
            let fleet = alg
                .solver()
                .solve(&fleet_ctx, &opts)
                .unwrap_or_else(|e| panic!("case {case} {alg:?} fleet path: {e}"));
            assert_eq!(
                legacy.placement.assignment, fleet.placement.assignment,
                "case {case} {alg:?}: assignments diverged between scenario and fleet"
            );
            assert_eq!(
                legacy.placement.objective.to_bits(),
                fleet.placement.objective.to_bits(),
                "case {case} {alg:?}: objective not bitwise identical ({} vs {})",
                legacy.placement.objective,
                fleet.placement.objective
            );
        }
    }
}

/// The acceptance fleet: two accelerator classes with different `speed`
/// and different `mem_cap`, plus a CPU pool.
fn hetero_request() -> PlanRequest {
    PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("fast", 2, 6.0).speed(2.0),
        DeviceClass::acc("slow", 2, 3.0),
        DeviceClass::cpu("cpu", 1),
    ]))
}

fn hetero_graph() -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..10 {
        g.add_node(Node::new(format!("n{i}")).cpu(20.0).acc(1.0).mem(1.0).comm(0.05));
    }
    for i in 1..10 {
        g.add_edge(i - 1, i);
    }
    g
}

#[test]
fn heterogeneous_fleet_end_to_end_dp_ip_pipedream() {
    let g = hetero_graph();
    let req = hetero_request();
    let opts = exact_opts();
    let mut svc = PlannerService::new(4);
    for alg in [Algorithm::Dp, Algorithm::IpContiguous, Algorithm::PipeDream] {
        let fixed = req.clone().algorithm(AlgoChoice::Fixed(alg));
        let r = svc
            .plan_request(&g, &fixed, &opts)
            .unwrap_or_else(|e| panic!("{alg:?} on heterogeneous fleet: {e}"));
        // per-class memory must hold: fast devices ≤ 6.0, slow ≤ 3.0
        r.placement
            .check_memory_req(&g, &req)
            .unwrap_or_else(|e| panic!("{alg:?}: {e}"));
        for i in 0..req.fleet.k() {
            let used = g.mem_of(&r.placement.set_of(Device::Acc(i), g.n()));
            let cap = req.fleet.acc_mem_cap(i);
            assert!(used <= cap + 1e-9, "{alg:?}: acc{i} holds {used} > {cap}");
        }
        assert!(r.placement.objective.is_finite(), "{alg:?} objective");
    }
    // all three shared one analysis context (same fingerprint)
    assert_eq!(svc.misses(), 1, "algorithm choice must not split the ctx cache");
    assert!(svc.hits() >= 2);
}

#[test]
fn dp_exploits_fast_class_and_respects_slow_caps() {
    // 10-node chain, 1 MB each: slow devices (cap 3) cannot take more
    // than 3 nodes; a speed-2 device doing 4 nodes has effective load 2.
    let g = hetero_graph();
    let req = hetero_request().algorithm(AlgoChoice::Fixed(Algorithm::Dp));
    let r = planner::plan_request(&g, &req, &exact_opts()).unwrap();
    r.placement.validate_req(&g, &req).unwrap();
    // uniform slow-only fleet for comparison: strictly worse or equal
    let slow_only = PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("slow", 4, 3.0),
        DeviceClass::cpu("cpu", 1),
    ]))
    .algorithm(AlgoChoice::Fixed(Algorithm::Dp));
    let slow_r = planner::plan_request(&g, &slow_only, &exact_opts()).unwrap();
    assert!(
        r.placement.objective <= slow_r.placement.objective + 1e-9,
        "fast class must not hurt: {} vs {}",
        r.placement.objective,
        slow_r.placement.objective
    );
}

#[test]
fn auto_algorithm_resolves_by_objective() {
    let g = hetero_graph();
    let opts = exact_opts();
    // throughput → exact DP
    let tp = hetero_request(); // Auto by default
    let r = planner::plan_request(&g, &tp, &opts).unwrap();
    assert!(
        r.placement.algorithm.contains("DP"),
        "auto/throughput resolved to {}",
        r.placement.algorithm
    );
    // latency → the latency IP
    let lat = hetero_request().objective(Objective::Latency);
    let r = planner::plan_request(&g, &lat, &opts).unwrap();
    assert!(
        r.placement.algorithm.contains("latency"),
        "auto/latency resolved to {}",
        r.placement.algorithm
    );
    // lattice blowup → DPL fallback (an antichain has 2^n ideals; cap it)
    let mut wide = OpGraph::new();
    for i in 0..24 {
        wide.add_node(Node::new(format!("w{i}")).cpu(8.0).acc(1.0));
    }
    let ctx = ProblemCtx::from_request_with_cap(wide.clone(), tp.clone(), 64);
    let r = planner::solve_request(&ctx, &tp, &opts).unwrap();
    assert_eq!(r.placement.algorithm, "DPL", "auto must fall back to DPL on lattice blowup");
}

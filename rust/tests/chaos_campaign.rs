//! ISSUE-6 acceptance: the seeded chaos campaign. 2 workloads × 2
//! heterogeneous fleets × 50 seeds = 200 fuzzed fail/slow/recover/spike
//! scripts through the monitored serving loop, asserting on every run:
//! no panic/deadlock, every injected sample completed or shed with a
//! classified cause, the swap count respects the hysteresis bound, and
//! clean single-permanent-fail runs land within the documented factor of
//! the oracle-replan-at-fault-time throughput (DESIGN.md §7).
//!
//! Everything is seed-fixed: a failure here reproduces with
//! `cargo run --release -- chaos <wl> dp --seed <seed> --runs 1`.

use dnn_partition::coordinator::context::SolveOpts;
use dnn_partition::coordinator::placement::{DeviceClass, Fleet, PlanRequest};
use dnn_partition::coordinator::planner::Algorithm;
use dnn_partition::graph::{Node, OpGraph};
use dnn_partition::runtime::server::ServingPlanner;
use dnn_partition::simx::chaos::{ChaosCampaign, ChaosConfig};
use dnn_partition::simx::Verdict;

fn chain(n: usize) -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..n {
        g.add_node(Node::new(format!("c{i}")).cpu(20.0).acc(1.0).mem(1.0).comm(0.05));
    }
    for i in 1..n {
        g.add_edge(i - 1, i);
    }
    g
}

fn training_chain(n: usize) -> OpGraph {
    dnn_partition::util::proptest::training_chain(
        n,
        &Node::new("f").cpu(20.0).acc(1.0).mem(1.0).comm(0.05),
        &Node::new("b").cpu(20.0).acc(1.5).mem(0.5).comm(0.05),
    )
}

/// Two heterogeneous fleets (speed-skewed classes + a CPU pool), caps
/// unlimited so shed causes stay about devices, not memory.
fn fleets() -> Vec<(&'static str, PlanRequest)> {
    vec![
        (
            "fast1-slow2",
            PlanRequest::new(Fleet::new(vec![
                DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
                DeviceClass::acc("slow", 2, f64::INFINITY),
                DeviceClass::cpu("cpu", 1),
            ])),
        ),
        (
            "a2-b2",
            PlanRequest::new(Fleet::new(vec![
                DeviceClass::acc("a", 2, f64::INFINITY).speed(3.0),
                DeviceClass::acc("b", 2, f64::INFINITY),
                DeviceClass::cpu("cpu", 1),
            ])),
        ),
    ]
}

fn workloads() -> Vec<(&'static str, OpGraph)> {
    vec![("chain8", chain(8)), ("train6", training_chain(6))]
}

#[test]
fn chaos_campaign_two_workloads_two_fleets() {
    let mut total_runs = 0usize;
    let mut total_completed = 0usize;
    for (wl_name, g) in workloads() {
        for (fl_name, req) in fleets() {
            let cfg = ChaosConfig {
                // distinct seed block per cell, all fixed
                seed: 0xC1A05
                    + (wl_name.len() as u64) * 1000
                    + fl_name.len() as u64,
                runs: 50,
                samples_min: 12,
                samples_max: 16,
                ..ChaosConfig::default()
            };
            let camp = ChaosCampaign::new(&g, &req, cfg);
            let mut planner = ServingPlanner::new(Algorithm::Dp, SolveOpts::default());
            let report = camp.run(&mut planner);
            assert_eq!(report.runs.len(), 50, "{wl_name}/{fl_name}");
            assert!(
                report.ok().is_ok(),
                "{wl_name}/{fl_name}: {:#?}",
                report.violations
            );
            for r in &report.runs {
                // every run terminated with the conservation law intact
                assert_eq!(
                    r.completed + r.shed,
                    r.injected,
                    "{wl_name}/{fl_name} seed {}",
                    r.seed
                );
                if r.verdict == Verdict::Completed {
                    assert_eq!(r.shed + r.completed, r.injected);
                    assert!(r.makespan.is_finite());
                }
            }
            total_runs += report.runs.len();
            total_completed += report.completed_runs;
        }
    }
    assert_eq!(total_runs, 200);
    // the generator must not be producing a degenerate campaign where
    // everything sheds: most fuzzed runs are survivable by construction
    // (fails capped at k-1, CPU pool present)
    assert!(
        total_completed * 2 > total_runs,
        "only {total_completed}/{total_runs} chaos runs completed"
    );
}

//! Property-based tests (in-tree harness, see `util::proptest`): the
//! paper's structural invariants checked over random DAGs.

use dnn_partition::algos::{dp, dpl, ip_throughput, objective};
use dnn_partition::coordinator::placement::{Device, Placement, Scenario};
use dnn_partition::graph::{contiguity, ideals, topo};
use dnn_partition::util::bitset::BitSet;
use dnn_partition::util::proptest::{check_dag, random_dag, random_training_dag};
use dnn_partition::util::rng::Rng;

#[test]
fn prop_fact_5_2_ideal_differences_are_exactly_contiguous_sets() {
    check_dag("fact-5.2", 25, 9, |g| {
        let lat = ideals::IdealLattice::enumerate(g, 100_000)
            .map_err(|_| "lattice blowup".to_string())?;
        // every nested ideal pair difference must be contiguous
        let mut rng = Rng::new(7);
        for _ in 0..40 {
            let a = rng.gen_range(lat.len());
            let b = rng.gen_range(lat.len());
            let small = lat.ideal_bitset(a.min(b));
            let big = lat.ideal_bitset(a.max(b));
            if small.is_subset(&big) {
                let s = big.difference(&small);
                if !contiguity::is_contiguous(g, &s) {
                    return Err(format!("non-contiguous ideal difference {s:?}"));
                }
                // and the Fact-5.2 decomposition round-trips
                if contiguity::to_ideal_pair(g, &s).is_none() && !s.is_empty() {
                    return Err(format!("to_ideal_pair failed on {s:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_every_ideal_is_downward_closed() {
    check_dag("ideal-closure", 25, 9, |g| {
        let lat = ideals::IdealLattice::enumerate(g, 100_000)
            .map_err(|_| "lattice blowup".to_string())?;
        for id in 0..lat.len() {
            let ideal = lat.ideal_bitset(id);
            if !ideals::is_ideal(g, &ideal) {
                return Err(format!("not downward closed: {ideal:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dp_placements_are_valid_and_pipeline_orderable() {
    check_dag("dp-validity", 20, 10, |g| {
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = dp::solve(g, &sc).map_err(|e| e.to_string())?;
        p.validate(g, &sc, true).map_err(|e| e)?;
        let dense = p.dense(sc.k);
        if !contiguity::partition_pipeline_orderable(g, &dense, sc.k + sc.l) {
            return Err("DP split not pipeline-orderable".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dpl_sandwiched_between_dp_and_infinity() {
    check_dag("dpl-bounds", 20, 10, |g| {
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let exact = dp::solve(g, &sc).map_err(|e| e.to_string())?.objective;
        let heur = dpl::solve(g, &sc).map_err(|e| e.to_string())?.objective;
        if heur < exact - 1e-9 {
            return Err(format!("DPL {heur} beat exact DP {exact}"));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_feasibility_respected_by_all_engines() {
    check_dag("memory", 15, 8, |g| {
        let sc = Scenario::new(2, 1, g.nodes.iter().map(|n| n.mem).sum::<f64>() / 2.5);
        if let Ok(p) = dp::solve(g, &sc) {
            p.check_memory(g, &sc).map_err(|e| format!("dp: {e}"))?;
        }
        if let Ok(r) = ip_throughput::solve(
            g,
            &sc,
            &ip_throughput::IpOptions {
                time_limit: std::time::Duration::from_millis(500),
                ..Default::default()
            },
        ) {
            r.placement.check_memory(g, &sc).map_err(|e| format!("ip: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_training_colocation_always_respected() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..15 {
        let g = random_training_dag(&mut rng, 7, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        if let Ok(p) = dp::solve(&g, &sc) {
            p.check_colocation(&g).unwrap();
        }
        if let Ok(p) = dpl::solve(&g, &sc) {
            p.check_colocation(&g).unwrap();
        }
    }
}

#[test]
fn prop_virtual_device_split_partitions_correctly() {
    let mut rng = Rng::new(0xF00D);
    for _ in 0..30 {
        let g = random_dag(&mut rng, 12, 0.25);
        // random subset
        let set = BitSet::from_iter(g.n(), (0..g.n()).filter(|_| rng.gen_bool(0.4)));
        let pieces = contiguity::virtual_device_split(&g, &set);
        let mut union = BitSet::new(g.n());
        for p in &pieces {
            assert!(contiguity::is_contiguous(&g, p), "piece not contiguous");
            assert!(!p.intersects(&union), "pieces overlap");
            union.union_with(p);
        }
        assert_eq!(union, set, "pieces don't cover the set");
    }
}

#[test]
fn prop_latency_at_least_critical_path() {
    let mut rng = Rng::new(0xCAFE);
    for _ in 0..20 {
        let g = random_dag(&mut rng, 10, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        // min-cost critical path is a lower bound for ANY placement
        let order = topo::toposort(&g).unwrap();
        let mut done = vec![0.0f64; g.n()];
        for &v in &order {
            let ready = g.preds[v].iter().map(|&u| done[u]).fold(0.0, f64::max);
            done[v] = ready + g.nodes[v].p_cpu.min(g.nodes[v].p_acc);
        }
        let lb = done.iter().copied().fold(0.0, f64::max);
        // random placement
        let p = Placement::new(
            (0..g.n())
                .map(|_| {
                    if rng.gen_bool(0.5) {
                        Device::Acc(rng.gen_range(2))
                    } else {
                        Device::Cpu(0)
                    }
                })
                .collect(),
            0.0,
            "random",
        );
        let lat = objective::latency(&g, &sc, &p);
        assert!(lat >= lb - 1e-9, "latency {lat} below critical path {lb}");
    }
}

/// Reference DP over the naive lattice: O(𝓘²) pairwise subset checks,
/// subgraph costs recomputed from scratch via `acc_load`/`cpu_load`. Slow
/// but obviously correct — the arena DP must reproduce it exactly.
fn naive_dp_objective(
    g: &dnn_partition::graph::OpGraph,
    sc: &Scenario,
    naive: &ideals::NaiveLattice,
) -> Option<f64> {
    let ni = naive.ideals.len();
    let (k, l) = (sc.k, sc.l);
    let slots = (k + 1) * (l + 1);
    let idx = |i: usize, k_: usize, l_: usize| i * slots + k_ * (l + 1) + l_;
    let mut dp = vec![f64::INFINITY; ni * slots];
    for c in dp[..slots].iter_mut() {
        *c = 0.0;
    }
    for i in 1..ni {
        // proper sub-ideals are strictly smaller, hence earlier in the
        // cardinality-sorted order
        for j in 0..i {
            if !naive.ideals[j].is_subset(&naive.ideals[i]) {
                continue;
            }
            let s = naive.ideals[i].difference(&naive.ideals[j]);
            if s.is_empty() {
                continue;
            }
            let acc = g.acc_load(&s, sc.mem_cap);
            let cpu = g.cpu_load(&s);
            for k_ in 0..=k {
                for l_ in 0..=l {
                    let cell = idx(i, k_, l_);
                    if k_ > 0 {
                        let cand = dp[idx(j, k_ - 1, l_)].max(acc);
                        if cand < dp[cell] {
                            dp[cell] = cand;
                        }
                    }
                    if l_ > 0 {
                        let cand = dp[idx(j, k_, l_ - 1)].max(cpu);
                        if cand < dp[cell] {
                            dp[cell] = cand;
                        }
                    }
                }
            }
        }
        // a device may stay empty
        for k_ in 0..=k {
            for l_ in 0..=l {
                let cell = idx(i, k_, l_);
                if k_ > 0 && dp[idx(i, k_ - 1, l_)] < dp[cell] {
                    dp[cell] = dp[idx(i, k_ - 1, l_)];
                }
                if l_ > 0 && dp[idx(i, k_, l_ - 1)] < dp[cell] {
                    dp[cell] = dp[idx(i, k_, l_ - 1)];
                }
            }
        }
    }
    let best = dp[idx(ni - 1, k, l)];
    best.is_finite().then_some(best)
}

#[test]
fn prop_arena_dp_matches_naive_reference_dp() {
    check_dag("arena-dp-vs-naive", 20, 8, |g| {
        let sc = Scenario::new(2, 1, g.nodes.iter().map(|n| n.mem).sum::<f64>() / 2.0);
        let lat = ideals::IdealLattice::enumerate(g, 100_000)
            .map_err(|_| "lattice blowup".to_string())?;
        let naive = ideals::enumerate_naive(g, 100_000)
            .map_err(|_| "naive blowup".to_string())?;
        if lat.len() != naive.ideals.len() {
            return Err(format!(
                "ideal counts differ: arena {} vs naive {}",
                lat.len(),
                naive.ideals.len()
            ));
        }
        let fast = dp::solve_on_lattice(g, &sc, &lat).ok().map(|(obj, _)| obj);
        let slow = naive_dp_objective(g, &sc, &naive);
        match (fast, slow) {
            (Some(a), Some(b)) if (a - b).abs() < 1e-9 => Ok(()),
            (None, None) => Ok(()),
            (a, b) => Err(format!("arena DP {a:?} vs naive DP {b:?}")),
        }
    });
}

#[test]
fn prop_parallel_dp_is_deterministic() {
    // The level-synchronous DP must return bitwise-identical tables for
    // any thread count: same objective, same reconstructed assignment.
    check_dag("dp-determinism", 12, 10, |g| {
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let lat = ideals::IdealLattice::enumerate(g, 100_000)
            .map_err(|_| "lattice blowup".to_string())?;
        let zeros = vec![0.0; g.n()];
        let mut results = Vec::new();
        for opts in [
            dp::DpOptions { threads: 1, par_threshold: usize::MAX },
            dp::DpOptions { threads: 2, par_threshold: 1 },
            dp::DpOptions { threads: 8, par_threshold: 1 },
        ] {
            results.push(dp::solve_on_lattice_with_opts(g, &sc, &lat, &zeros, &opts).ok());
        }
        for r in &results[1..] {
            match (&results[0], r) {
                (Some((a, da)), Some((b, db))) => {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("objectives differ: {a} vs {b}"));
                    }
                    if da != db {
                        return Err("assignments differ across thread counts".into());
                    }
                }
                (None, None) => {}
                _ => return Err("feasibility differs across thread counts".into()),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_max_load_monotone_in_device_count() {
    let mut rng = Rng::new(0xABCD);
    for _ in 0..10 {
        let g = random_dag(&mut rng, 9, 0.3);
        let few = dp::solve(&g, &Scenario::new(1, 1, f64::INFINITY)).map(|p| p.objective);
        let many = dp::solve(&g, &Scenario::new(3, 1, f64::INFINITY)).map(|p| p.objective);
        if let (Ok(a), Ok(b)) = (few, many) {
            assert!(b <= a + 1e-9, "more devices made things worse: {b} > {a}");
        }
    }
}

//! Property-based tests (in-tree harness, see `util::proptest`): the
//! paper's structural invariants checked over random DAGs.

use dnn_partition::algos::{dp, dpl, ip_throughput, objective};
use dnn_partition::coordinator::placement::{Device, Placement, Scenario};
use dnn_partition::graph::{contiguity, ideals, topo};
use dnn_partition::util::bitset::BitSet;
use dnn_partition::util::proptest::{check_dag, random_dag, random_training_dag};
use dnn_partition::util::rng::Rng;

#[test]
fn prop_fact_5_2_ideal_differences_are_exactly_contiguous_sets() {
    check_dag("fact-5.2", 25, 9, |g| {
        let lat = ideals::IdealLattice::enumerate(g, 100_000)
            .map_err(|_| "lattice blowup".to_string())?;
        // every nested ideal pair difference must be contiguous
        let mut rng = Rng::new(7);
        for _ in 0..40 {
            let a = rng.gen_range(lat.len());
            let b = rng.gen_range(lat.len());
            let (small, big) = (&lat.ideals[a.min(b)], &lat.ideals[a.max(b)]);
            if small.is_subset(big) {
                let s = big.difference(small);
                if !contiguity::is_contiguous(g, &s) {
                    return Err(format!("non-contiguous ideal difference {s:?}"));
                }
                // and the Fact-5.2 decomposition round-trips
                if contiguity::to_ideal_pair(g, &s).is_none() && !s.is_empty() {
                    return Err(format!("to_ideal_pair failed on {s:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_every_ideal_is_downward_closed() {
    check_dag("ideal-closure", 25, 9, |g| {
        let lat = ideals::IdealLattice::enumerate(g, 100_000)
            .map_err(|_| "lattice blowup".to_string())?;
        for ideal in &lat.ideals {
            if !ideals::is_ideal(g, ideal) {
                return Err(format!("not downward closed: {ideal:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dp_placements_are_valid_and_pipeline_orderable() {
    check_dag("dp-validity", 20, 10, |g| {
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let p = dp::solve(g, &sc).map_err(|e| e.to_string())?;
        p.validate(g, &sc, true).map_err(|e| e)?;
        let dense = p.dense(sc.k);
        if !contiguity::partition_pipeline_orderable(g, &dense, sc.k + sc.l) {
            return Err("DP split not pipeline-orderable".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dpl_sandwiched_between_dp_and_infinity() {
    check_dag("dpl-bounds", 20, 10, |g| {
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let exact = dp::solve(g, &sc).map_err(|e| e.to_string())?.objective;
        let heur = dpl::solve(g, &sc).map_err(|e| e.to_string())?.objective;
        if heur < exact - 1e-9 {
            return Err(format!("DPL {heur} beat exact DP {exact}"));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_feasibility_respected_by_all_engines() {
    check_dag("memory", 15, 8, |g| {
        let sc = Scenario::new(2, 1, g.nodes.iter().map(|n| n.mem).sum::<f64>() / 2.5);
        if let Ok(p) = dp::solve(g, &sc) {
            p.check_memory(g, &sc).map_err(|e| format!("dp: {e}"))?;
        }
        if let Ok(r) = ip_throughput::solve(
            g,
            &sc,
            &ip_throughput::IpOptions {
                time_limit: std::time::Duration::from_millis(500),
                ..Default::default()
            },
        ) {
            r.placement.check_memory(g, &sc).map_err(|e| format!("ip: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_training_colocation_always_respected() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..15 {
        let g = random_training_dag(&mut rng, 7, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        if let Ok(p) = dp::solve(&g, &sc) {
            p.check_colocation(&g).unwrap();
        }
        if let Ok(p) = dpl::solve(&g, &sc) {
            p.check_colocation(&g).unwrap();
        }
    }
}

#[test]
fn prop_virtual_device_split_partitions_correctly() {
    let mut rng = Rng::new(0xF00D);
    for _ in 0..30 {
        let g = random_dag(&mut rng, 12, 0.25);
        // random subset
        let set = BitSet::from_iter(g.n(), (0..g.n()).filter(|_| rng.gen_bool(0.4)));
        let pieces = contiguity::virtual_device_split(&g, &set);
        let mut union = BitSet::new(g.n());
        for p in &pieces {
            assert!(contiguity::is_contiguous(&g, p), "piece not contiguous");
            assert!(!p.intersects(&union), "pieces overlap");
            union.union_with(p);
        }
        assert_eq!(union, set, "pieces don't cover the set");
    }
}

#[test]
fn prop_latency_at_least_critical_path() {
    let mut rng = Rng::new(0xCAFE);
    for _ in 0..20 {
        let g = random_dag(&mut rng, 10, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        // min-cost critical path is a lower bound for ANY placement
        let order = topo::toposort(&g).unwrap();
        let mut done = vec![0.0f64; g.n()];
        for &v in &order {
            let ready = g.preds[v].iter().map(|&u| done[u]).fold(0.0, f64::max);
            done[v] = ready + g.nodes[v].p_cpu.min(g.nodes[v].p_acc);
        }
        let lb = done.iter().copied().fold(0.0, f64::max);
        // random placement
        let p = Placement::new(
            (0..g.n())
                .map(|_| {
                    if rng.gen_bool(0.5) {
                        Device::Acc(rng.gen_range(2))
                    } else {
                        Device::Cpu(0)
                    }
                })
                .collect(),
            0.0,
            "random",
        );
        let lat = objective::latency(&g, &sc, &p);
        assert!(lat >= lb - 1e-9, "latency {lat} below critical path {lb}");
    }
}

#[test]
fn prop_max_load_monotone_in_device_count() {
    let mut rng = Rng::new(0xABCD);
    for _ in 0..10 {
        let g = random_dag(&mut rng, 9, 0.3);
        let few = dp::solve(&g, &Scenario::new(1, 1, f64::INFINITY)).map(|p| p.objective);
        let many = dp::solve(&g, &Scenario::new(3, 1, f64::INFINITY)).map(|p| p.objective);
        if let (Ok(a), Ok(b)) = (few, many) {
            assert!(b <= a + 1e-9, "more devices made things worse: {b} > {a}");
        }
    }
}

//! ISSUE-4 acceptance: the legacy `pipeline::sim` API is a thin adapter
//! over the `simx` engine, and on uniform fleets the engine reproduces
//! the frozen PR-0 greedy list scheduler (`simulate_reference`) within ε.
//!
//! ε = 1e-9 relative: both implementations schedule identical task sets
//! with identical costs under the same selection discipline, so any
//! divergence beyond float noise is a semantic regression.

use dnn_partition::algos::dp;
use dnn_partition::coordinator::placement::{Device, Placement, Scenario};
use dnn_partition::graph::{Node, OpGraph};
use dnn_partition::pipeline::sim::{self, Schedule};

const EPS: f64 = 1e-9;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= EPS * b.abs().max(1.0)
}

fn assert_equivalent(g: &OpGraph, sc: &Scenario, p: &Placement, schedule: Schedule, n: usize) {
    let engine = sim::simulate(g, sc, p, schedule, n);
    let reference = sim::simulate_reference(g, sc, p, schedule, n);
    assert_eq!(engine.sample_done.len(), reference.sample_done.len(), "{schedule:?}");
    for (s, (&a, &b)) in engine
        .sample_done
        .iter()
        .zip(reference.sample_done.iter())
        .enumerate()
    {
        assert!(
            close(a, b),
            "{schedule:?}: sample {s} finished at {a} (engine) vs {b} (reference)"
        );
    }
    assert!(
        close(engine.total, reference.total),
        "{schedule:?}: total {} vs {}",
        engine.total,
        reference.total
    );
    assert!(
        close(engine.steady_tps, reference.steady_tps),
        "{schedule:?}: steady {} vs {}",
        engine.steady_tps,
        reference.steady_tps
    );
    // same tasks executed (trace order may differ at simultaneous starts)
    assert_eq!(engine.trace.len(), reference.trace.len(), "{schedule:?}");
}

fn chain(n: usize) -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..n {
        g.add_node(Node::new(format!("c{i}")).cpu(10.0).acc(1.0).mem(1.0).comm(0.1));
    }
    for i in 1..n {
        g.add_edge(i - 1, i);
    }
    g
}

/// Training chain (shared shape from `util::proptest::training_chain`).
fn training_chain(n: usize) -> OpGraph {
    dnn_partition::util::proptest::training_chain(
        n,
        &Node::new("f").cpu(10.0).acc(1.0).mem(1.0).comm(0.1),
        &Node::new("b").cpu(10.0).acc(1.5).mem(0.5).comm(0.1),
    )
}

#[test]
fn inference_chain_all_schedules_match_reference() {
    let g = chain(8);
    let sc = Scenario::new(4, 1, f64::INFINITY);
    let p = dp::solve(&g, &sc).unwrap();
    for (schedule, n) in [
        (Schedule::Pipelined, 40),
        (Schedule::SingleStream, 6),
        (Schedule::GPipe, 12),       // no backwards: degenerates to pipelined
        (Schedule::PipeDream1F1B, 12),
    ] {
        assert_equivalent(&g, &sc, &p, schedule, n);
    }
}

#[test]
fn noncontiguous_virtual_devices_match_reference() {
    // Fig. 5b: interleaved devices — two pieces per real device
    let g = chain(6);
    let sc = Scenario::new(2, 0, f64::INFINITY);
    let p = Placement::new(
        vec![
            Device::Acc(0),
            Device::Acc(0),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(0),
            Device::Acc(0),
        ],
        0.0,
        "manual",
    );
    assert_equivalent(&g, &sc, &p, Schedule::Pipelined, 30);
    assert_equivalent(&g, &sc, &p, Schedule::SingleStream, 5);
}

#[test]
fn training_chain_1f1b_and_gpipe_match_reference() {
    let g = training_chain(6);
    let sc = Scenario::new(3, 1, f64::INFINITY);
    let p = dp::solve(&g, &sc).unwrap();
    assert_equivalent(&g, &sc, &p, Schedule::PipeDream1F1B, 24);
    assert_equivalent(&g, &sc, &p, Schedule::GPipe, 12);
    assert_equivalent(&g, &sc, &p, Schedule::SingleStream, 4);
}

#[test]
fn mixed_cpu_accelerator_placement_matches_reference() {
    // CPU device in the pipeline: the paper's k accelerators + 1 CPU
    let g = chain(6);
    let sc = Scenario::new(2, 1, f64::INFINITY);
    let p = Placement::new(
        vec![
            Device::Cpu(0),
            Device::Acc(0),
            Device::Acc(0),
            Device::Acc(1),
            Device::Acc(1),
            Device::Cpu(0),
        ],
        0.0,
        "manual",
    );
    assert_equivalent(&g, &sc, &p, Schedule::Pipelined, 30);
}

#[test]
fn adapter_keeps_piece_decomposition_identical() {
    let g = chain(6);
    let sc = Scenario::new(2, 0, f64::INFINITY);
    let p = Placement::new(
        vec![
            Device::Acc(0),
            Device::Acc(0),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(0),
            Device::Acc(0),
        ],
        0.0,
        "manual",
    );
    let pieces = sim::build_pieces(&g, &sc, &p);
    let via_req = dnn_partition::simx::build_pieces_req(&g, &sc.to_request(), &p);
    assert_eq!(pieces.len(), via_req.len());
    for (a, b) in pieces.iter().zip(via_req.iter()) {
        assert_eq!(a.real_device, b.real_device);
        assert_eq!(a.deps, b.deps);
        assert_eq!(a.fw_cost.to_bits(), b.fw_cost.to_bits(), "fw cost must be bitwise");
        assert_eq!(a.bw_cost.to_bits(), b.bw_cost.to_bits(), "bw cost must be bitwise");
    }
}

//! PR-2 cache-layer guarantees: planning through a cold [`ProblemCtx`]
//! and planning against a [`PlannerService`] cache hit must be
//! *bitwise* identical, for every registered solver — the analysis cache
//! may never change a result, only its cost.

use dnn_partition::baselines::expert::ExpertStyle;
use dnn_partition::coordinator::context::{ProblemCtx, SolveOpts, Solver};
use dnn_partition::coordinator::placement::Scenario;
use dnn_partition::coordinator::planner::{self, Algorithm};
use dnn_partition::coordinator::service::PlannerService;
use dnn_partition::util::proptest::random_dag;
use dnn_partition::util::rng::Rng;
use std::time::Duration;

fn exact_opts() -> SolveOpts {
    SolveOpts {
        ip_budget: Duration::from_secs(10),
        // gap 0 ⇒ the IPs run to proven optimality on these small graphs,
        // which makes their output deterministic (no budget-dependent cut)
        gap_target: 0.0,
        expert: Some(ExpertStyle::EqualStripes),
        ..SolveOpts::default()
    }
}

#[test]
fn every_solver_bitwise_identical_cold_ctx_vs_cache_hit() {
    let mut rng = Rng::new(0x5EED);
    let opts = exact_opts();
    for case in 0..4 {
        let g = random_dag(&mut rng, 8, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        for alg in Algorithm::ALL {
            // cold: a fresh context, nothing shared
            let cold_ctx = ProblemCtx::new(g.clone(), sc.clone());
            let cold = alg
                .solver()
                .solve(&cold_ctx, &opts)
                .unwrap_or_else(|e| panic!("case {case} {alg:?} cold: {e}"));
            // service path: first plan warms the cache, second one hits it
            let mut svc = PlannerService::new(2);
            svc.plan(&g, &sc, alg, &opts)
                .unwrap_or_else(|e| panic!("case {case} {alg:?} warm-up: {e}"));
            let hit = svc
                .plan(&g, &sc, alg, &opts)
                .unwrap_or_else(|e| panic!("case {case} {alg:?} hit: {e}"));
            assert!(svc.hits() >= 1, "case {case} {alg:?}: second plan missed the cache");
            assert_eq!(
                cold.placement.assignment, hit.placement.assignment,
                "case {case} {alg:?}: assignments diverged between cold ctx and cache hit"
            );
            assert_eq!(
                cold.placement.objective.to_bits(),
                hit.placement.objective.to_bits(),
                "case {case} {alg:?}: objective not bitwise identical ({} vs {})",
                cold.placement.objective,
                hit.placement.objective
            );
        }
    }
}

#[test]
fn ctx_solvers_match_deprecated_free_functions() {
    // The thin compatibility wrappers and the ctx-based registry solvers
    // must agree on the deterministic engines.
    use dnn_partition::algos::{dp, dpl};
    let mut rng = Rng::new(0xFACE);
    for _ in 0..6 {
        let g = random_dag(&mut rng, 9, 0.3);
        let sc = Scenario::new(2, 1, f64::INFINITY);
        let ctx = ProblemCtx::new(g.clone(), sc.clone());
        let opts = SolveOpts::default();

        let via_ctx = Algorithm::Dp.solver().solve(&ctx, &opts).unwrap();
        let via_free = dp::solve(&g, &sc).unwrap();
        assert_eq!(via_ctx.placement.assignment, via_free.assignment);
        assert_eq!(via_ctx.placement.objective.to_bits(), via_free.objective.to_bits());

        let via_ctx = Algorithm::Dpl.solver().solve(&ctx, &opts).unwrap();
        let via_free = dpl::solve(&g, &sc).unwrap();
        assert_eq!(via_ctx.placement.assignment, via_free.assignment);
        assert_eq!(via_ctx.placement.objective.to_bits(), via_free.objective.to_bits());
    }
}

#[test]
fn service_plan_matches_one_shot_planner_on_real_workload() {
    use dnn_partition::workloads::table1_workloads;
    let w = table1_workloads().into_iter().find(|w| w.name == "BERT-24" && !w.training).unwrap();
    let one_shot = planner::plan(&w, Algorithm::Dp, Duration::from_secs(2)).unwrap();
    let mut svc = PlannerService::default();
    let opts = SolveOpts::default();
    let via_service = svc.plan_workload(&w, Algorithm::Dp, &opts).unwrap();
    assert_eq!(one_shot.placement.assignment, via_service.placement.assignment);
    assert_eq!(
        one_shot.placement.objective.to_bits(),
        via_service.placement.objective.to_bits()
    );
    // and the hit is identical again
    let hit = svc.plan_workload(&w, Algorithm::Dp, &opts).unwrap();
    assert_eq!(via_service.placement.assignment, hit.placement.assignment);
}

//! PR-10 resilience contracts (DESIGN.md §11): deadline-aware anytime
//! planning, panic isolation, and admission control over the concurrent
//! service.
//!
//! * **Budget-off equivalence.** With no [`SolveBudget`] set, every
//!   registry solver through the service is bitwise identical to a direct
//!   solver call — the budget plumbing and unwind envelopes must be
//!   invisible when unused.
//! * **Deadlines degrade, never fail.** A 1 ms deadline on an IP-hard
//!   instance answers through the anytime search or the degradation
//!   ladder — never an error, never a hang.
//! * **Anytime × warm start.** A node-limit-truncated solve stores its
//!   incumbent; a larger-budget re-solve is never worse and
//!   bitwise-matches an unbudgeted cold solve once the search closes.
//! * **Panic isolation.** An injected solver panic fails exactly the
//!   poisoned fingerprint's requests; everything else keeps planning and
//!   the `hits + misses + dedup_waits == requests` accounting stays exact.
//! * **Waiters always wake.** A context build that panics completes the
//!   single-flight entry with the error — every deduped waiter returns
//!   `Err`, none hang, and the fingerprint retries cleanly afterwards.
//! * **Admission control.** Past `max_concurrent` + `max_queue`, requests
//!   shed with [`PlaceError::Overloaded`] instead of queueing unboundedly.
//!
//! The fault-injection hook is process-wide, so the tests that arm it
//! serialize behind one mutex and disarm it on every exit path.

use dnn_partition::algos::PlaceError;
use dnn_partition::baselines::expert::ExpertStyle;
use dnn_partition::coordinator::concurrent::{
    set_fault_hook, AdmissionLimits, ConcurrentService, FaultPoint,
};
use dnn_partition::coordinator::context::{
    fingerprint_req, PlanQuality, PlanRung, ProblemCtx, SolveBudget, SolveOpts,
};
use dnn_partition::coordinator::placement::{AlgoChoice, Fleet, Objective, PlanRequest, Scenario};
use dnn_partition::coordinator::planner::Algorithm;
use dnn_partition::util::proptest::random_dag;
use dnn_partition::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

/// Serializes the tests that install the process-wide fault hook.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Disarms the fault hook on drop, so a failing assertion cannot leave a
/// panicking hook armed for the rest of the process.
struct HookGuard;

impl Drop for HookGuard {
    fn drop(&mut self) {
        set_fault_hook(None);
    }
}

fn exact_opts() -> SolveOpts {
    SolveOpts {
        ip_budget: Duration::from_secs(10),
        // gap 0 ⇒ the IPs close these small instances to proven
        // optimality, making re-solves comparable bitwise
        gap_target: 0.0,
        expert: Some(ExpertStyle::EqualStripes),
        ..SolveOpts::default()
    }
}

#[test]
fn unbudgeted_service_solves_match_direct_solver_calls_for_every_algorithm() {
    let mut rng = Rng::new(0xBEEF01);
    let g = random_dag(&mut rng, 8, 0.3);
    let sc = Scenario::new(2, 1, f64::INFINITY);
    let opts = exact_opts();
    assert!(opts.budget.is_unlimited(), "this sweep is the budget-off contract");

    let ctx = ProblemCtx::from_request(g.clone(), sc.to_request());
    let svc = ConcurrentService::new(4, 16);
    for alg in Algorithm::ALL {
        let direct = alg.solver().solve(&ctx, &opts).unwrap();
        let via_svc = svc.plan(&g, &sc, alg, &opts).unwrap();
        assert_eq!(
            direct.placement.objective.to_bits(),
            via_svc.placement.objective.to_bits(),
            "{alg:?}: unbudgeted service solve must be bitwise identical"
        );
        assert_eq!(
            direct.placement.assignment, via_svc.placement.assignment,
            "{alg:?}: assignments must match"
        );
        assert_eq!(
            via_svc.quality,
            PlanQuality::Exact,
            "{alg:?}: an untruncated solve is exact quality"
        );
    }
}

#[test]
fn millisecond_deadline_on_hard_instance_answers_without_error() {
    let mut rng = Rng::new(0xDEAD11);
    // large enough that the contiguous IP cannot close it in 1 ms
    let g = random_dag(&mut rng, 22, 0.35);
    let req = PlanRequest::new(Fleet::uniform(4, 1, f64::INFINITY))
        .objective(Objective::Throughput)
        .algorithm(AlgoChoice::Auto);
    let svc = ConcurrentService::new(2, 8);
    let opts = SolveOpts {
        ip_budget: Duration::from_secs(10),
        budget: SolveBudget::deadline_in(Duration::from_millis(1)),
        ..SolveOpts::default()
    };
    let r = svc
        .plan_request(&g, &req, &opts)
        .expect("a deadline may degrade the answer, never lose it");
    assert!(!r.placement.assignment.is_empty());
    // Exact is allowed (the machine may be fast enough), but most runs
    // land on an anytime rung; either way the request answered.
    match r.quality {
        PlanQuality::Exact | PlanQuality::Anytime(_) => {}
    }
}

#[test]
fn already_expired_deadline_degrades_to_the_greedy_floor() {
    let mut rng = Rng::new(0xDEAD22);
    let g = random_dag(&mut rng, 10, 0.3);
    let req = PlanRequest::new(Fleet::uniform(3, 1, f64::INFINITY))
        .objective(Objective::Throughput)
        .algorithm(AlgoChoice::Auto);
    let svc = ConcurrentService::new(2, 8);
    let opts = SolveOpts {
        budget: SolveBudget::deadline_in(Duration::ZERO),
        ..SolveOpts::default()
    };
    let r = svc.plan_request(&g, &req, &opts).expect("the ladder floor always answers");
    assert_eq!(
        r.quality,
        PlanQuality::Anytime(PlanRung::Greedy),
        "an expired deadline goes straight to the greedy floor"
    );
}

#[test]
fn node_limit_truncation_is_anytime_and_warm_start_stays_monotone() {
    let mut rng = Rng::new(0xA11CE);
    let g = random_dag(&mut rng, 10, 0.3);
    let req = PlanRequest::new(Fleet::uniform(2, 1, f64::INFINITY))
        .objective(Objective::Throughput)
        .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous));
    let svc = ConcurrentService::new(1, 4);

    // node limits are deterministic (unlike wall-clock deadlines), so the
    // truncation point — and hence this test — is reproducible
    let truncated_opts = SolveOpts {
        gap_target: 0.0,
        budget: SolveBudget { deadline: None, node_limit: Some(1) },
        ..exact_opts()
    };
    let truncated = svc
        .plan_request(&g, &req, &truncated_opts)
        .expect("the warm-started incumbent answers even a 1-node search");
    assert_eq!(
        truncated.quality,
        PlanQuality::Anytime(PlanRung::Ip),
        "a node-capped search that returns is anytime quality"
    );
    assert_eq!(svc.seeds_len(), 1, "the truncated solve must store its incumbent");

    // re-solve with the budget lifted: resumes from the stored incumbent,
    // closes the search, and may never be worse than the truncated answer
    let full_opts = exact_opts();
    let full = svc.plan_request(&g, &req, &full_opts).unwrap();
    assert_eq!(full.quality, PlanQuality::Exact);
    assert!(
        full.placement.objective <= truncated.placement.objective + 1e-12,
        "a longer-budget re-solve must never be worse than the truncated one"
    );

    // once closed, the warm-started answer is bitwise the cold unbudgeted
    // answer — truncation must leave no trace in the final optimum
    let cold_svc = ConcurrentService::new(1, 4);
    let cold = cold_svc.plan_request(&g, &req, &full_opts).unwrap();
    assert_eq!(
        full.placement.objective.to_bits(),
        cold.placement.objective.to_bits(),
        "closed warm-started solve must bitwise-match the cold solve"
    );
    assert_eq!(full.placement.assignment, cold.placement.assignment);
}

#[test]
fn injected_solver_panic_fails_only_the_poisoned_fingerprint() {
    let _serial = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _disarm = HookGuard;
    let mut rng = Rng::new(0xFA57);
    let g = random_dag(&mut rng, 8, 0.3);
    let reqs: Vec<PlanRequest> = (2..=4)
        .map(|k| {
            PlanRequest::new(Fleet::uniform(k, 1, f64::INFINITY))
                .objective(Objective::Throughput)
                .algorithm(AlgoChoice::Fixed(Algorithm::Dp))
        })
        .collect();
    let poisoned_fp = fingerprint_req(&g, &reqs[1]);
    set_fault_hook(Some(Arc::new(move |point, fp| {
        if point == FaultPoint::Solve && fp == poisoned_fp {
            panic!("injected solver fault");
        }
    })));

    let svc = ConcurrentService::new(4, 16);
    let opts = SolveOpts::default();
    let rounds = 4;
    let panicked = AtomicUsize::new(0);
    let answered = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            scope.spawn(|| {
                for _ in 0..rounds {
                    for req in &reqs {
                        match svc.plan_request(&g, req, &opts) {
                            Ok(_) => {
                                answered.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(PlaceError::SolverPanicked(_))
                                if fingerprint_req(&g, req) == poisoned_fp =>
                            {
                                panicked.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => panic!("healthy request failed: {e}"),
                        }
                    }
                }
            });
        }
    });
    let total = 4 * rounds * reqs.len();
    assert_eq!(
        panicked.load(Ordering::Relaxed),
        4 * rounds,
        "every solve of the poisoned fingerprint fails with SolverPanicked"
    );
    assert_eq!(
        answered.load(Ordering::Relaxed),
        2 * 4 * rounds,
        "every other request keeps planning"
    );
    assert_eq!(
        svc.hits() + svc.misses() + svc.dedup_waits(),
        total,
        "the cache accounting identity survives injected panics"
    );
}

#[test]
fn context_build_panic_wakes_every_deduped_waiter_with_the_error() {
    let _serial = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _disarm = HookGuard;
    let mut rng = Rng::new(0xF1167);
    let g = random_dag(&mut rng, 8, 0.3);
    let sc = Scenario::new(3, 1, f64::INFINITY);
    let fp = fingerprint_req(&g, &sc.to_request());
    set_fault_hook(Some(Arc::new(move |point, hook_fp| {
        if point == FaultPoint::ContextBuild && hook_fp == fp {
            panic!("injected context-build fault");
        }
    })));

    let svc = ConcurrentService::new(2, 8);
    let workers = 6;
    let gate = Barrier::new(workers);
    // all workers request the same uncached fingerprint at once: one
    // becomes the builder and panics; the rest dedup onto its flight (or
    // retry the build) and every single one must return Err — the
    // "waiters always wake" invariant. A hang here is the regression.
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                gate.wait();
                let r = svc.context(&g, &sc);
                assert!(
                    matches!(r, Err(PlaceError::SolverPanicked(_))),
                    "a dead builder must surface as SolverPanicked, got {r:?}"
                );
            });
        }
    });
    assert!(svc.is_empty(), "a panicked build must not cache anything");

    // disarm and retry: the fingerprint was never poisoned into the cache
    set_fault_hook(None);
    let ctx = svc.context(&g, &sc).expect("the next request rebuilds cleanly");
    assert_eq!(ctx.fingerprint(), fp);
}

#[test]
fn overload_sheds_with_overloaded_instead_of_queueing_unboundedly() {
    let _serial = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _disarm = HookGuard;
    let mut rng = Rng::new(0x10AD);
    let g = random_dag(&mut rng, 8, 0.3);
    let reqs: Vec<PlanRequest> = (2..=5)
        .map(|k| {
            PlanRequest::new(Fleet::uniform(k, 1, f64::INFINITY))
                .objective(Objective::Throughput)
                .algorithm(AlgoChoice::Fixed(Algorithm::Dp))
        })
        .collect();
    let fps: Vec<u64> = reqs.iter().map(|r| fingerprint_req(&g, r)).collect();
    // hold each admitted solve long enough that the others arrive while
    // the single slot is taken (the hook fires inside the permit's scope)
    set_fault_hook(Some(Arc::new(move |point, fp| {
        if point == FaultPoint::Solve && fps.contains(&fp) {
            std::thread::sleep(Duration::from_millis(300));
        }
    })));

    let svc = ConcurrentService::new(4, 16).with_admission(AdmissionLimits {
        max_concurrent: 1,
        max_queue: 0,
        per_tenant: 0,
    });
    let opts = SolveOpts::default();
    let gate = Barrier::new(reqs.len());
    let ok = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for req in &reqs {
            scope.spawn(|| {
                gate.wait();
                match svc.plan_request(&g, req, &opts) {
                    Ok(_) => {
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(PlaceError::Overloaded) => {
                        shed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => panic!("unexpected error under overload: {e}"),
                }
            });
        }
    });
    assert_eq!(ok.load(Ordering::Relaxed) + shed.load(Ordering::Relaxed), reqs.len());
    assert!(ok.load(Ordering::Relaxed) >= 1, "the admitted request completes");
    assert!(
        shed.load(Ordering::Relaxed) >= 1,
        "with one slot and no queue, simultaneous requests must shed"
    );
    assert_eq!(
        svc.shed(),
        shed.load(Ordering::Relaxed),
        "the service's shed counter matches what callers observed"
    );
}

#[test]
fn per_tenant_cap_sheds_the_hot_fingerprint_only() {
    let _serial = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _disarm = HookGuard;
    let mut rng = Rng::new(0x7E4A47);
    let g = random_dag(&mut rng, 8, 0.3);
    let hot = PlanRequest::new(Fleet::uniform(2, 1, f64::INFINITY))
        .objective(Objective::Throughput)
        .algorithm(AlgoChoice::Fixed(Algorithm::Dp));
    let hot_fp = fingerprint_req(&g, &hot);
    set_fault_hook(Some(Arc::new(move |point, fp| {
        if point == FaultPoint::Solve && fp == hot_fp {
            std::thread::sleep(Duration::from_millis(300));
        }
    })));

    // plenty of slots and queue, but one in-flight solve per tenant
    let svc = ConcurrentService::new(4, 16).with_admission(AdmissionLimits {
        max_concurrent: 8,
        max_queue: 8,
        per_tenant: 1,
    });
    let opts = SolveOpts::default();
    let workers = 4;
    let gate = Barrier::new(workers);
    let ok = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                gate.wait();
                match svc.plan_request(&g, &hot, &opts) {
                    Ok(_) => {
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(PlaceError::Overloaded) => {
                        shed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => panic!("unexpected error: {e}"),
                }
            });
        }
    });
    assert_eq!(ok.load(Ordering::Relaxed) + shed.load(Ordering::Relaxed), workers);
    assert!(ok.load(Ordering::Relaxed) >= 1);
    assert!(
        shed.load(Ordering::Relaxed) >= 1,
        "a hot tenant past its in-flight cap is shed, not queued"
    );
}

//! Cross-module integration tests: optimizers vs each other on real
//! workloads, JSON round-trips through the planner, simulator-vs-objective
//! agreement, and CLI-level planning flows.

use dnn_partition::algos::{dp, dpl, ip_throughput, objective};
use dnn_partition::baselines::{expert, greedy, local_search, pipedream, scotch_like};
use dnn_partition::coordinator::placement::Scenario;
use dnn_partition::coordinator::planner::{self, Algorithm};
use dnn_partition::pipeline::sim::{self, Schedule};
use dnn_partition::util::json::Json;
use dnn_partition::workloads::{self, json as wjson, table1_workloads, Granularity};
use std::time::Duration;

#[test]
fn dp_beats_or_matches_every_baseline_on_all_layer_workloads() {
    // Inference: the DP is exactly optimal, so NO baseline may beat it.
    // Training: the DP optimizes the merged fw/bw communication proxy
    // (PipeDream-style, DESIGN.md §3) but is scored on the exact
    // objective, so baselines may edge it out by the proxy error — bound
    // that discrepancy at 5%.
    for w in table1_workloads() {
        if w.granularity != Granularity::Layer || w.name == "InceptionV3" {
            continue; // Inception's lattice is too big for a quick test
        }
        let p = dp::solve_with_cap(&w.graph, &w.scenario, 500_000).unwrap();
        p.validate(&w.graph, &w.scenario, true).unwrap();
        let slack = if w.training { 0.95 } else { 1.0 - 1e-12 };
        let baselines = [
            local_search::solve(&w.graph, &w.scenario, 3, 1).objective,
            pipedream::solve(&w.graph, &w.scenario).objective,
            scotch_like::solve(&w.graph, &w.scenario, 2).objective,
            w.expert
                .map(|s| expert::solve(&w.graph, &w.scenario, s).objective)
                .unwrap_or(f64::INFINITY),
        ];
        for (i, b) in baselines.iter().enumerate() {
            assert!(
                *b >= p.objective * slack,
                "{} ({}) baseline {i} ({b}) beat DP ({}) beyond proxy slack",
                w.name,
                if w.training { "training" } else { "inference" },
                p.objective
            );
        }
    }
}

#[test]
fn dpl_loss_is_small_on_paper_workloads() {
    // paper: DPL is lossless for most workloads, ≤9% worst case
    for w in table1_workloads() {
        if w.granularity != Granularity::Layer || w.name == "InceptionV3" {
            continue;
        }
        let exact = dp::solve_with_cap(&w.graph, &w.scenario, 500_000).unwrap();
        let heur = dpl::solve(&w.graph, &w.scenario).unwrap();
        let loss = heur.objective / exact.objective - 1.0;
        // training rows can go slightly negative (proxy scoring, see
        // dp_beats_or_matches_every_baseline_on_all_layer_workloads)
        let lo = if w.training { -0.05 } else { -1e-9 };
        assert!(
            (lo..0.25).contains(&loss),
            "{}: DPL loss {:.1}% out of range",
            w.name,
            loss * 100.0
        );
    }
}

#[test]
fn simulator_validates_cost_model_on_bert24() {
    // the central claim behind the max-load objective (§5.1)
    let w = table1_workloads().into_iter().find(|w| w.name == "BERT-24" && !w.training).unwrap();
    let p = dp::solve(&w.graph, &w.scenario).unwrap();
    let res = sim::simulate(&w.graph, &w.scenario, &p, Schedule::Pipelined, 48);
    let err = (res.steady_tps - p.objective).abs() / p.objective;
    assert!(err < 0.05, "steady {} vs predicted {}", res.steady_tps, p.objective);
}

#[test]
fn training_simulation_matches_objective_bert24() {
    let w = table1_workloads().into_iter().find(|w| w.name == "BERT-24" && w.training).unwrap();
    let p = dp::solve(&w.graph, &w.scenario).unwrap();
    let res = sim::simulate(&w.graph, &w.scenario, &p, Schedule::PipeDream1F1B, 32);
    let err = (res.steady_tps - p.objective).abs() / p.objective;
    assert!(err < 0.1, "steady {} vs predicted {}", res.steady_tps, p.objective);
}

#[test]
fn json_roundtrip_preserves_planning_result() {
    let w = table1_workloads().into_iter().find(|w| w.name == "GNMT" && !w.training).unwrap();
    let before = dp::solve(&w.graph, &w.scenario).unwrap().objective;
    let json_text = wjson::to_json(&w).to_string();
    let (g2, sc2, _) = wjson::from_json(&Json::parse(&json_text).unwrap()).unwrap();
    let after = dp::solve(&g2, &sc2).unwrap().objective;
    assert!((before - after).abs() < 1e-9, "{before} vs {after}");
}

#[test]
fn planner_facade_runs_ip_with_budget() {
    let w = table1_workloads().into_iter().find(|w| w.name == "BERT-24" && !w.training).unwrap();
    let r = planner::plan(&w, Algorithm::IpNonContiguous, Duration::from_secs(2)).unwrap();
    assert!(r.placement.objective.is_finite());
    assert!(r.gap.is_some());
    // non-contiguous never worse than the DP
    let dp_r = planner::plan(&w, Algorithm::Dp, Duration::from_secs(2)).unwrap();
    assert!(r.placement.objective <= dp_r.placement.objective + 1e-9);
}

#[test]
fn latency_scenarios_force_real_splits() {
    // §7: single-accelerator placement must be infeasible
    for w in table1_workloads().into_iter().filter(|w| !w.training) {
        let sc = workloads::latency_scenario(&w.graph);
        let model: f64 = w.graph.nodes.iter().map(|n| n.mem).sum();
        assert!(model > sc.mem_cap, "{}: model fits one accelerator", w.name);
        // greedy must still find something feasible
        let g = greedy::solve(&w.graph, &sc);
        g.check_memory(&w.graph, &sc).unwrap();
    }
}

#[test]
fn overlap_comm_model_never_hurts() {
    // App. C.1: max(compute, comm) ≤ compute + comm pointwise ⇒ optimum ≤
    let w = table1_workloads().into_iter().find(|w| w.name == "ResNet50" && w.granularity == Granularity::Layer && !w.training).unwrap();
    let seq = dp::solve(&w.graph, &w.scenario).unwrap().objective;
    let sc2 = Scenario {
        comm_model: dnn_partition::coordinator::placement::CommModel::Overlap,
        ..w.scenario.clone()
    };
    let ovl = dp::solve(&w.graph, &sc2).unwrap().objective;
    assert!(ovl <= seq + 1e-9, "overlap {ovl} > sequential {seq}");
}

#[test]
fn ip_noncontiguous_improves_or_ties_contiguous_on_op_graph() {
    let w = table1_workloads().into_iter().find(|w| w.name == "BERT-3" && !w.training).unwrap();
    let c = ip_throughput::solve(
        &w.graph,
        &w.scenario,
        &ip_throughput::IpOptions { time_limit: Duration::from_secs(3), ..Default::default() },
    )
    .unwrap();
    let nc = ip_throughput::solve(
        &w.graph,
        &w.scenario,
        &ip_throughput::IpOptions {
            contiguous: false,
            time_limit: Duration::from_secs(3),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(nc.placement.objective <= c.placement.objective + 1e-9);
}

#[test]
fn objective_consistency_between_evaluator_and_loads() {
    let w = table1_workloads().into_iter().find(|w| w.name == "GNMT" && !w.training).unwrap();
    let p = dp::solve(&w.graph, &w.scenario).unwrap();
    let via_loads = objective::DeviceLoads::of(&w.graph, &w.scenario, &p);
    let nd = w.scenario.k + w.scenario.l;
    let manual = (0..nd).map(|i| via_loads.device_total(i, &w.scenario)).fold(0.0, f64::max);
    assert!((manual - objective::max_load(&w.graph, &w.scenario, &p)).abs() < 1e-9);
}

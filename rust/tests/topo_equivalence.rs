//! ISSUE-8 acceptance tests for the device-interconnect topology subsystem.
//!
//! 1. **Uniform-topology equivalence**: every registry solver is *bitwise*
//!    identical planning on a fleet with `topo=uniform:X` vs the same
//!    fleet with no topology at all — the per-pair cost path degenerates
//!    to the scalar path exactly (`s * 1.0 + 0.0 == s` in IEEE-754), on
//!    both random DAGs and a heterogeneous multi-class fleet.
//! 2. **Islands validation**: on a 2-island fleet, every validated
//!    solver's predicted max-load still agrees with its simx steady-state
//!    TPS within the documented 10% tolerance.
//! 3. **Pair-aware placements win**: on an interleaved 2-island fleet
//!    with an 8× inter/intra bandwidth gap, a topology-aware solver's
//!    placement, simulated on the real topology, strictly beats the
//!    placement a topology-blind solve produces when replayed on the same
//!    topology.
//! 4. **Round-trips**: `Fleet::parse → Display → parse` and
//!    `fleet_to_json → fleet_from_json` preserve the topology; unknown
//!    `key=` clauses and shape-mismatched specs are rejected loudly; the
//!    planning-service fingerprint separates topologized contexts.

use dnn_partition::algos::objective;
use dnn_partition::baselines::expert::ExpertStyle;
use dnn_partition::coordinator::context::{ProblemCtx, SolveOpts, Solver};
use dnn_partition::coordinator::placement::{
    AlgoChoice, DeviceClass, Fleet, PlanRequest,
};
use dnn_partition::coordinator::planner::{self, Algorithm};
use dnn_partition::coordinator::service::PlannerService;
use dnn_partition::graph::{Node, OpGraph};
use dnn_partition::simx::engine::{self, Schedule, SimConfig};
use dnn_partition::simx::validate::{self, DEFAULT_TOLERANCE};
use dnn_partition::topo::Topology;
use dnn_partition::util::proptest::random_dag;
use dnn_partition::util::rng::Rng;
use dnn_partition::workloads::json::{fleet_from_json, fleet_to_json};
use std::time::Duration;

fn exact_opts() -> SolveOpts {
    SolveOpts {
        ip_budget: Duration::from_secs(10),
        // gap 0 ⇒ the IPs run to proven optimality on these small graphs,
        // making their output deterministic
        gap_target: 0.0,
        expert: Some(ExpertStyle::EqualStripes),
        ..SolveOpts::default()
    }
}

/// `n`-node chain with the given per-node boundary transfer cost.
fn chain(n: usize, comm: f64) -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..n {
        g.add_node(Node::new(format!("n{i}")).cpu(50.0).acc(1.0).mem(1.0).comm(comm));
    }
    for i in 1..n {
        g.add_edge(i - 1, i);
    }
    g
}

fn solve_bitwise_pair(g: &OpGraph, plain: &PlanRequest, topo: &PlanRequest, tag: &str) {
    let opts = exact_opts();
    for alg in Algorithm::ALL {
        let a = alg
            .solver()
            .solve(&ProblemCtx::from_request(g.clone(), plain.clone()), &opts)
            .unwrap_or_else(|e| panic!("{tag} {alg:?} no-topology path: {e}"));
        let b = alg
            .solver()
            .solve(&ProblemCtx::from_request(g.clone(), topo.clone()), &opts)
            .unwrap_or_else(|e| panic!("{tag} {alg:?} uniform-topology path: {e}"));
        assert_eq!(
            a.placement.assignment, b.placement.assignment,
            "{tag} {alg:?}: assignments diverged under a uniform topology"
        );
        assert_eq!(
            a.placement.objective.to_bits(),
            b.placement.objective.to_bits(),
            "{tag} {alg:?}: objective not bitwise identical ({} vs {})",
            a.placement.objective,
            b.placement.objective
        );
    }
}

#[test]
fn every_registry_solver_bitwise_identical_uniform_topology_vs_none() {
    let mut rng = Rng::new(0x70B0);
    // infinite caps keep all 12 solvers feasible on random graphs (same
    // reasoning as tests/fleet_equivalence.rs)
    let classes = || {
        vec![DeviceClass::acc("acc", 2, f64::INFINITY), DeviceClass::cpu("cpu", 1)]
    };
    for case in 0..3 {
        let g = random_dag(&mut rng, 8, 0.3);
        let plain = PlanRequest::new(Fleet::new(classes()));
        let topo = PlanRequest::new(
            Fleet::new(classes()).topology(Topology::uniform(3, 5.0).unwrap()),
        );
        solve_bitwise_pair(&g, &plain, &topo, &format!("case {case}"));
    }
}

#[test]
fn heterogeneous_fleet_bitwise_identical_under_uniform_topo_clause() {
    let g = chain(10, 0.05);
    let plain = PlanRequest::new(Fleet::parse("2xfast@2,2xslow,1xcpu").unwrap());
    let topo =
        PlanRequest::new(Fleet::parse("2xfast@2,2xslow,1xcpu,topo=uniform:900").unwrap());
    assert!(topo.fleet.topology.is_some(), "topo= clause must materialize");
    solve_bitwise_pair(&g, &plain, &topo, "hetero");
}

#[test]
fn islands_fleet_predictions_validate_against_simulation() {
    // Small boundary costs relative to compute: the model charges comm
    // into device loads while the engine serializes it on links, and the
    // 10% tolerance covers that plus slope noise (DESIGN.md §6).
    let g = chain(10, 0.01);
    let req =
        PlanRequest::new(Fleet::parse("4xacc,1xcpu,topo=islands:2x2@800/200").unwrap());
    let report = validate::validate_request(
        &g,
        &req,
        &[Algorithm::Dp, Algorithm::IpContiguous, Algorithm::PipeDream],
        &exact_opts(),
        160,
        DEFAULT_TOLERANCE,
    )
    .unwrap();
    assert!(report.skipped.is_empty(), "skipped on islands fleet: {:?}", report.skipped);
    assert_eq!(report.rows.len(), 3);
    assert!(
        report.all_within(),
        "prediction-vs-simulation drifted past {}: worst {:?}",
        report.tolerance,
        report.worst()
    );
}

#[test]
fn pair_aware_placement_beats_uniform_model_replay_on_islands() {
    // Interleaved islands {0,2} / {1,3} with an 8× inter/intra gap: the
    // dense-order contiguous split a topology-blind solver produces
    // crosses islands on EVERY chain boundary, while a pair-aware solver
    // can group stages within an island.
    let g = chain(4, 0.5);
    let topo_fleet = Fleet::parse("4xacc,1xcpu,topo=islands:0.2|1.3@800/100").unwrap();
    assert!(topo_fleet.max_comm_slowdown() >= 4.0, "acceptance fleet needs a >=4x gap");
    let mut blind_fleet = topo_fleet.clone();
    blind_fleet.topology = None;
    let opts = exact_opts();

    // Topology-blind plan, replayed on the real interconnect.
    let blind_req =
        PlanRequest::new(blind_fleet).algorithm(AlgoChoice::Fixed(Algorithm::Dp));
    let blind = planner::plan_request(&g, &blind_req, &opts).unwrap();
    let topo_req = PlanRequest::new(topo_fleet);
    let cfg = SimConfig::for_request(&topo_req);
    let blind_sim = engine::simulate_req(
        &g,
        &topo_req,
        &blind.placement,
        Schedule::Pipelined,
        200,
        &cfg,
    );
    let blind_rescore = objective::max_load_req(&g, &topo_req, &blind.placement);

    // Pair-aware plans on the same fleet.
    let mut best_sim = f64::INFINITY;
    let mut best_obj = f64::INFINITY;
    for alg in [Algorithm::IpContiguous, Algorithm::IpNonContiguous, Algorithm::LocalSearch]
    {
        let fixed = topo_req.clone().algorithm(AlgoChoice::Fixed(alg));
        let r = planner::plan_request(&g, &fixed, &opts)
            .unwrap_or_else(|e| panic!("{alg:?} on islands fleet: {e}"));
        let sim = engine::simulate_req(
            &g,
            &topo_req,
            &r.placement,
            Schedule::Pipelined,
            200,
            &cfg,
        );
        best_sim = best_sim.min(sim.steady_tps);
        best_obj = best_obj.min(r.placement.objective);
    }

    // Model level: the pair-exact objective of the aware plan beats the
    // blind plan re-scored on the topology.
    assert!(
        best_obj < blind_rescore - 1e-9,
        "aware objective {best_obj} must beat blind re-score {blind_rescore}"
    );
    // Execution level (the ISSUE acceptance bar): simulated steady-state
    // time-per-sample of the aware placement strictly beats the blind
    // placement replayed on the same topology.
    assert!(
        best_sim < blind_sim.steady_tps - 1e-9,
        "aware simulated {best_sim} must beat blind replay {}",
        blind_sim.steady_tps
    );
}

#[test]
fn fleet_parse_display_roundtrip_with_topology() {
    for spec in [
        "2xacc:4,1xcpu",
        "4xacc,1xcpu,topo=islands:2x2@800/100",
        "4xacc,1xcpu,topo=islands:0.2|1.3@800/100",
        "2xfast@2:6,2xslow:3,1xcpu,topo=uniform:900",
        "8xacc:32768,1xcpu,topo=tiered:2x2x2@900/64/8",
        "2xacc,1xcpu,topo=matrix:0;4;1/4;0;1/1;1;0",
    ] {
        let f = Fleet::parse(spec).unwrap_or_else(|e| panic!("parse '{spec}': {e}"));
        let shown = f.to_string();
        let rt = Fleet::parse(&shown)
            .unwrap_or_else(|e| panic!("re-parse '{shown}' (from '{spec}'): {e}"));
        assert_eq!(f, rt, "Display round-trip drifted for '{spec}' (showed '{shown}')");
    }
}

#[test]
fn bad_fleet_clauses_are_rejected() {
    // unknown key= clause
    assert!(Fleet::parse("2xacc,1xcpu,frob=3").is_err());
    // island shape covers 8 accelerators, fleet has 4
    assert!(Fleet::parse("4xacc,1xcpu,topo=islands:2x4@900/64").is_err());
    // malformed spec
    assert!(Fleet::parse("2xacc,1xcpu,topo=ring:4@10").is_err());
}

#[test]
fn fleet_json_roundtrip_with_topology() {
    for spec in [
        "2xacc:4,1xcpu,bw=2",
        "4xacc:8,1xcpu,topo=islands:2x2@800/100",
        "2xfast@2:6,2xslow:3,1xcpu,topo=uniform:900",
        "4xacc:8,1xcpu,topo=matrix:0;4;1;1;1/4;0;1;1;1/1;1;0;4;1/1;1;4;0;1/1;1;1;1;0",
    ] {
        let f = Fleet::parse(spec).unwrap_or_else(|e| panic!("parse '{spec}': {e}"));
        let back = fleet_from_json(&fleet_to_json(&f))
            .unwrap_or_else(|e| panic!("json round-trip '{spec}': {e}"));
        assert_eq!(f, back, "JSON round-trip drifted for '{spec}'");
    }
}

#[test]
fn topology_splits_the_service_fingerprint() {
    let g = chain(6, 0.1);
    let opts = exact_opts();
    let mut svc = PlannerService::new(4);
    let plain = PlanRequest::new(Fleet::parse("2xacc,1xcpu").unwrap());
    let topo = PlanRequest::new(Fleet::parse("2xacc,1xcpu,topo=uniform:5").unwrap());
    svc.plan_request(&g, &plain, &opts).unwrap();
    svc.plan_request(&g, &topo, &opts).unwrap();
    // a topologized fleet must NOT alias the bare fleet's cached context,
    // even when the topology is cost-identical (uniform)
    assert_eq!(svc.misses(), 2, "topology must be part of the context fingerprint");
    svc.plan_request(&g, &topo, &opts).unwrap();
    assert!(svc.hits() >= 1, "identical topologized requests must still hit");
}

//! Regenerates **Figure 8**: the Table-2 ratios as bar charts (ASCII) —
//! throughput of each technique relative to the contiguous DP (1.00x),
//! four panels: (a) op/inference, (b) op/training, (c) layer/inference,
//! (d) layer/training. Also emits `fig8.csv` for external plotting.

use dnn_partition::algos::{dp, dpl, ip_throughput};
use dnn_partition::baselines::{expert, local_search, pipedream, scotch_like};
use dnn_partition::workloads::{table1_workloads, Granularity};
use std::fmt::Write as _;
use std::time::Duration;

fn bar(ratio: f64) -> String {
    let n = (ratio * 24.0).round().clamp(0.0, 60.0) as usize;
    "█".repeat(n)
}

fn main() {
    let budget = Duration::from_secs(
        std::env::var("F8_IP_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(8),
    );
    let mut csv = String::from("panel,workload,technique,relative_throughput\n");
    for (panel, op, training) in [
        ("(a) operator graphs, inference", Granularity::Operator, false),
        ("(b) operator graphs, training", Granularity::Operator, true),
        ("(c) layer graphs, inference", Granularity::Layer, false),
        ("(d) layer graphs, training", Granularity::Layer, true),
    ] {
        println!("\n## Fig. 8 {panel} — throughput relative to DP (contiguous)");
        for w in table1_workloads() {
            if w.granularity != op || w.training != training {
                continue;
            }
            let base = match dp::solve_with_cap(&w.graph, &w.scenario, 20_000)
                .or_else(|_| dpl::solve(&w.graph, &w.scenario))
            {
                Ok(p) => p.objective,
                Err(_) => continue,
            };
            println!("{}:", w.name);
            let mut emit = |label: &str, tps: f64| {
                let r = base / tps;
                println!("  {label:<18} {r:>5.2}x |{}", bar(r));
                let _ = writeln!(csv, "{panel},{},{label},{r:.4}", w.name);
            };
            emit("DP (contiguous)", base);
            if let Ok(r) = ip_throughput::solve(
                &w.graph,
                &w.scenario,
                &ip_throughput::IpOptions {
                    contiguous: false,
                    time_limit: budget,
                    ..Default::default()
                },
            ) {
                emit("IP (non-contig)", r.placement.objective);
            }
            if let Ok(p) = dpl::solve(&w.graph, &w.scenario) {
                emit("DPL", p.objective);
            }
            if let Some(style) = w.expert {
                emit("Expert", expert::solve(&w.graph, &w.scenario, style).objective);
            }
            emit("Local search", local_search::solve(&w.graph, &w.scenario, 10, 1).objective);
            if w.granularity == Granularity::Layer {
                emit("PipeDream", pipedream::solve(&w.graph, &w.scenario).objective);
            }
            emit("Scotch", scotch_like::solve(&w.graph, &w.scenario, 2).objective);
        }
    }
    std::fs::write("fig8.csv", csv).unwrap();
    println!("\nwrote fig8.csv");
}

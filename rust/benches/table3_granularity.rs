//! Regenerates **Table 3**: throughput advantage of optimizing at operator
//! granularity vs the layer-contracted graph (§6.2). For each operator
//! workload, contract ops into their layers (the generator records
//! `layer_of`), run the DP on both, and report the gain of the finer
//! graph. Expected shape: gains of 0–8%, larger for deeper models.

use dnn_partition::algos::dp;
use dnn_partition::graph::{contract, NodeKind};
use dnn_partition::workloads::{table1_workloads, Granularity};

fn main() {
    println!("# Table 3 — operator- vs layer-granularity optimization (TPS, contiguous DP)");
    println!("{:<12} {:>10} {:>12} {:>12} {:>6}", "workload", "task", "op-graph", "layer-contr", "gain");
    for w in table1_workloads() {
        if w.granularity != Granularity::Operator {
            continue;
        }
        let Some(layer_of) = &w.layer_of else { continue };
        let cap = 400_000;
        let fine = match dp::solve_with_cap(&w.graph, &w.scenario, cap) {
            Ok(p) => p.objective,
            Err(_) => continue,
        };
        // contract ops into layers — forward and backward parts of a layer
        // stay SEPARATE nodes (as in the paper's layer graphs), colocated
        // via a shared color class so the DP keeps them on one device.
        let mut dense_ids: std::collections::BTreeMap<usize, usize> = Default::default();
        let group_of: Vec<usize> = (0..w.graph.n())
            .map(|v| {
                let key =
                    layer_of[v] * 2 + (w.graph.nodes[v].kind == NodeKind::Backward) as usize;
                let next = dense_ids.len();
                *dense_ids.entry(key).or_insert(next)
            })
            .collect();
        let mut con = contract::contract_groups(&w.graph, &group_of);
        for (gi, members) in con.groups.iter().enumerate() {
            let layer = layer_of[members[0]] as u32;
            con.graph.nodes[gi].color_class = Some(layer);
            if con.graph.nodes[gi].kind == NodeKind::Backward {
                // partner = the forward node of the same layer, if any
                con.graph.nodes[gi].fw_partner = (0..con.graph.n()).find(|&o| {
                    con.graph.nodes[o].kind == NodeKind::Forward
                        && con.graph.nodes[o].color_class == Some(layer)
                });
            }
        }
        let coarse = match dp::solve_with_cap(&con.graph, &w.scenario, cap) {
            Ok(p) => p.objective,
            Err(_) => continue,
        };
        let gain = (coarse / fine - 1.0) * 100.0;
        println!(
            "{:<12} {:>10} {:>12.2} {:>12.2} {:>5.0}%",
            w.name,
            if w.training { "training" } else { "inference" },
            fine,
            coarse,
            gain
        );
    }
}

//! Regenerates the **Appendix** experiments:
//!
//! * App. A — GPipe objective `max FW + max BW` vs PipeDream objective
//!   `max(FW+BW)` on the training workloads (paper: ≤ ~6% apart).
//! * App. C.1 — interleaved communication (load = max instead of sum).
//! * App. C.2 — replication DP: sparse vs dense models.
//! * App. C.3 — accelerator hierarchies: slowdown vs inter-cluster factor.

use dnn_partition::algos::{dp, hierarchy, replication};
use dnn_partition::coordinator::placement::{CommModel, Scenario, TrainSchedule};
use dnn_partition::workloads::{table1_workloads, Granularity};

fn main() {
    // --- Appendix A ---
    println!("# Appendix A — PipeDream vs GPipe objective on the same optimal split");
    println!("{:<14} {:>12} {:>12} {:>7}", "workload", "max(FW+BW)", "maxFW+maxBW", "delta");
    for w in table1_workloads() {
        if !w.training || w.granularity != Granularity::Layer {
            continue;
        }
        let sc_pd = Scenario { train_schedule: TrainSchedule::PipeDream, ..w.scenario.clone() };
        let sc_gp = Scenario { train_schedule: TrainSchedule::GPipe, ..w.scenario.clone() };
        let Ok(p) = dp::solve_with_cap(&w.graph, &w.scenario, 20_000) else { continue };
        let pd = dnn_partition::algos::objective::max_load(&w.graph, &sc_pd, &p);
        let gp = dnn_partition::algos::objective::max_load(&w.graph, &sc_gp, &p);
        println!("{:<14} {:>12.2} {:>12.2} {:>6.1}%", w.name, pd, gp, (gp / pd - 1.0) * 100.0);
    }

    // --- Appendix C.1 ---
    println!("\n# Appendix C.1 — communication/computation interleaving (BERT-24 training)");
    let g = dnn_partition::workloads::bert::bert24_layer_graph(true);
    for (model, name) in [
        (CommModel::Sequential, "sequential (sum)"),
        (CommModel::Overlap, "overlap (max)"),
        (CommModel::FullDuplex, "full duplex"),
    ] {
        let sc = Scenario { comm_model: model, k: 6, l: 1, ..Default::default() };
        let p = dp::solve(&g, &sc).unwrap();
        println!("  {name:<18} optimal TPS {:.3}", p.objective);
    }

    // --- Appendix C.2 ---
    println!("\n# Appendix C.2 — replication (hybrid model/data parallelism)");
    println!("  bandwidth  plain-DP  replication-DP  replicated-stages");
    for bw in [0.1, 100.0, 1e5] {
        let sc = Scenario { k: 6, l: 0, bandwidth: bw, ..Default::default() };
        let plain = dp::solve(&g, &sc).unwrap().objective;
        let rep = replication::solve(&g, &sc, 20_000).unwrap();
        let nrep = rep.stage_devices.iter().filter(|d| d.len() > 1).count();
        println!("  {bw:>9} {plain:>9.3} {:>15.3} {nrep:>18}", rep.objective);
    }

    // --- Appendix C.3 ---
    println!("\n# Appendix C.3 — accelerator hierarchy (2 clusters x 3 accs, BERT-24 training)");
    println!("  inter-cluster slowdown  optimal TPS");
    for factor in [1.0, 4.0, 16.0, 64.0] {
        let hier = hierarchy::Hierarchy {
            num_clusters: 2,
            accs_per_cluster: 3,
            inter_factor: factor,
            mem_cap: 16.0 * 1024.0,
        };
        match hierarchy::solve(&g, &hier, 20_000) {
            Ok(r) => println!("  {factor:>22} {:>12.3}", r.objective),
            Err(e) => println!("  {factor:>22}  failed: {e}"),
        }
    }
}

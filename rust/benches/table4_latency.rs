//! Regenerates **Table 4**: single-sample latency minimization under
//! memory-bound accelerators (§7) — the latency IP vs Greedy, max-load DP,
//! Scotch-like and Expert, with MIP-gap reporting.
//!
//! Expected shape: the IP never loses to a baseline; max-load DP is the
//! strongest baseline most rows; Scotch violates memory (daggers).
//! Env knobs: `T4_IP_SECS` (default 8), `T4_FILTER`.

use dnn_partition::algos::{dp, ip_latency, objective};
use dnn_partition::baselines::{expert, greedy, scotch_like};
use dnn_partition::util::bench::paper_runtime;
use dnn_partition::workloads::{latency_scenario, table1_workloads};
use std::time::Duration;

fn main() {
    let ip_secs: u64 =
        std::env::var("T4_IP_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(8);
    let filter = std::env::var("T4_FILTER").unwrap_or_default();

    println!("# Table 4 — single-query inference latency (memory-bound accelerators)");
    println!(
        "{:<12} {:>5} {:>3} {:>7} | {:>9} {:>11} {:>11} {:>9} | {:>9} {:>7} {:>6} {:>6}",
        "workload", "nodes", "k", "M(MB)", "Greedy", "MaxLoadDP", "Scotch", "Expert", "IP", "IP-t", "gap", "gain"
    );

    for w in table1_workloads() {
        if w.training {
            continue; // §7 uses the inference workloads
        }
        if !filter.is_empty() && !w.name.contains(&filter) {
            continue;
        }
        let g = &w.graph;
        let sc = latency_scenario(g);

        let gr = greedy::solve(g, &sc);
        let ml_placement = dp::solve_with_cap(g, &sc, 20_000).ok();
        let ml = ml_placement.as_ref().map(|p| objective::latency(g, &sc, p));
        let sco = scotch_like::solve_latency(g, &sc, 7);
        let sco_viol = scotch_like::memory_violation(g, &sc, &sco);
        let exp = w.expert.map(|style| {
            let p = expert::solve_latency(g, &sc, style);
            (p.objective, scotch_like::memory_violation(g, &sc, &p))
        });

        let mut warm = vec![gr.clone()];
        warm.extend(ml_placement.clone());
        let opts = ip_latency::LatencyIpOptions {
            time_limit: Duration::from_secs(ip_secs),
            warm_starts: warm,
            ..Default::default()
        };
        let ip = ip_latency::solve(g, &sc, &opts);
        let (ip_lat, ip_t, ip_gap) = match &ip {
            Ok(r) => (r.placement.objective, paper_runtime(r.elapsed), r.gap),
            Err(_) => (f64::NAN, "-".into(), f64::NAN),
        };
        let best_baseline = [Some(gr.objective), ml, Some(sco.objective), exp.map(|e| e.0)]
            .into_iter()
            .flatten()
            .fold(f64::INFINITY, f64::min);
        let gain = (best_baseline / ip_lat - 1.0) * 100.0;

        let dag = |v: f64, viol: f64| {
            if viol > 3.0 {
                "OOM".to_string()
            } else if viol > 1.0 {
                format!("{v:.1}†")
            } else {
                format!("{v:.1}")
            }
        };
        println!(
            "{:<12} {:>5} {:>3} {:>7.0} | {:>9.1} {:>11} {:>11} {:>9} | {:>9.1} {:>7} {:>5.0}% {:>5.0}%",
            w.name,
            g.n(),
            sc.k,
            sc.mem_cap,
            gr.objective,
            ml.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
            dag(sco.objective, sco_viol),
            exp.map(|(v, viol)| dag(v, viol)).unwrap_or_else(|| "-".into()),
            ip_lat,
            ip_t,
            ip_gap * 100.0,
            gain,
        );
    }
    println!("† = memory constraints violated (Scotch/Expert ignore M, as in the paper)");
}

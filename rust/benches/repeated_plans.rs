//! PR-2 cache bench: repeated planning through the fingerprint-cached
//! [`PlannerService`] vs cold per-call analysis — the serving-time
//! re-planning loop (Moirai-style scenario churn over one model).
//!
//! Two measurements per workload:
//!
//! * `cold` — every iteration builds a fresh service, so each plan pays
//!   preprocessing + lattice enumeration + the DP solve;
//! * `hit`  — one persistent service; each iteration re-plans the same
//!   `(graph, scenario)` and only pays fingerprinting + cached-solution
//!   expansion.
//!
//! The acceptance bar for ISSUE 2 is ≥ 5× on the hit path. A third row
//! sweeps degraded scenarios (device loss, halved memory) against the
//! persistent service to show mixed hit/miss behavior.

use dnn_partition::coordinator::context::SolveOpts;
use dnn_partition::coordinator::placement::Scenario;
use dnn_partition::coordinator::planner::Algorithm;
use dnn_partition::coordinator::service::PlannerService;
use dnn_partition::util::bench::bench;
use dnn_partition::workloads::table1_workloads;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(
        std::env::var("RP_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1500),
    );
    let opts = SolveOpts::default();
    let algs = [Algorithm::Dp, Algorithm::Dpl];

    for want in ["BERT-24", "ResNet50", "GNMT"] {
        let Some(w) = table1_workloads()
            .into_iter()
            .find(|w| w.name == want && !w.training
                && w.granularity == dnn_partition::workloads::Granularity::Layer)
        else {
            continue;
        };
        let name = w.name.clone();

        let cold = bench(&format!("plan/cold/{name}"), budget, 3, || {
            let mut svc = PlannerService::new(1);
            algs.iter()
                .map(|&a| svc.plan(&w.graph, &w.scenario, a, &opts).unwrap().placement.objective)
                .sum::<f64>()
        });

        let mut svc = PlannerService::default();
        for &a in &algs {
            svc.plan(&w.graph, &w.scenario, a, &opts).unwrap();
        }
        let hit = bench(&format!("plan/hit/{name}"), budget, 3, || {
            algs.iter()
                .map(|&a| svc.plan(&w.graph, &w.scenario, a, &opts).unwrap().placement.objective)
                .sum::<f64>()
        });
        let speedup = cold.median.as_secs_f64() / hit.median.as_secs_f64().max(1e-12);
        println!("plan/speedup/{name}: {speedup:.1}x (cold {:?} -> hit {:?})", cold.median, hit.median);

        // scenario churn: device loss + halved memory, persistent service
        let scenarios: Vec<Scenario> = vec![
            w.scenario.clone(),
            Scenario { k: w.scenario.k.saturating_sub(1).max(1), ..w.scenario.clone() },
            Scenario { mem_cap: w.scenario.mem_cap / 2.0, ..w.scenario.clone() },
        ];
        let mut churn_svc = PlannerService::default();
        bench(&format!("plan/scenario-churn/{name}"), budget, 3, || {
            scenarios
                .iter()
                .map(|sc| {
                    churn_svc
                        .plan(&w.graph, sc, Algorithm::Dp, &opts)
                        .map(|r| r.placement.objective)
                        .unwrap_or(f64::NAN)
                })
                .sum::<f64>()
        });
        println!(
            "plan/cache-stats/{name}: {} hits / {} misses",
            churn_svc.hits(),
            churn_svc.misses()
        );
    }
}

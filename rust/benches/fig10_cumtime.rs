//! Regenerates **Figure 10** (Appendix A): cumulative forward and backward
//! layer times for the ResNet-50 training layer graph — the correlation
//! argument behind using max(FW+BW) as a proxy for the GPipe objective.
//! Prints an ASCII plot and writes `fig10.csv`.

use dnn_partition::graph::{topo, NodeKind};
use dnn_partition::workloads::resnet;
use std::fmt::Write as _;

fn main() {
    let g = resnet::resnet50_layer_graph(true);
    let order = topo::toposort(&g).unwrap();
    let fw: Vec<f64> = order
        .iter()
        .filter(|&&v| g.nodes[v].kind == NodeKind::Forward)
        .map(|&v| g.nodes[v].p_acc)
        .collect();
    // backward in forward order (bw nodes are mirrored; walk partners)
    let mut bw = vec![0.0; fw.len()];
    let fw_ids: Vec<usize> = order
        .iter()
        .copied()
        .filter(|&v| g.nodes[v].kind == NodeKind::Forward)
        .collect();
    for v in 0..g.n() {
        if let (NodeKind::Backward, Some(f)) = (g.nodes[v].kind, g.nodes[v].fw_partner) {
            if let Some(pos) = fw_ids.iter().position(|&x| x == f) {
                bw[pos] = g.nodes[v].p_acc;
            }
        }
    }
    let mut cum_fw = 0.0;
    let mut cum_bw = 0.0;
    let mut csv = String::from("layer,cum_forward_ms,cum_backward_ms\n");
    let total_fw: f64 = fw.iter().sum();
    let total_bw: f64 = bw.iter().sum();
    println!("# Fig. 10 — cumulative fw/bw times, ResNet-50 layer graph");
    println!("layer  cumFW(ms)  cumBW(ms)   (F = forward curve, B = backward)");
    for (i, (f, b)) in fw.iter().zip(&bw).enumerate() {
        cum_fw += f;
        cum_bw += b;
        let _ = writeln!(csv, "{i},{cum_fw:.4},{cum_bw:.4}");
        if i % 10 == 0 || i + 1 == fw.len() {
            let fpos = (cum_fw / total_fw * 50.0) as usize;
            let bpos = (cum_bw / total_bw * 50.0) as usize;
            let mut row = vec![' '; 52];
            row[fpos.min(51)] = 'F';
            row[bpos.min(51)] = if row[bpos.min(51)] == 'F' { '*' } else { 'B' };
            println!("{i:>5}  {cum_fw:>9.2}  {cum_bw:>9.2}  |{}|", row.iter().collect::<String>());
        }
    }
    // correlation of increments (the App-A argument)
    let n = fw.len() as f64;
    let (mf, mb) = (total_fw / n, total_bw / n);
    let cov: f64 = fw.iter().zip(&bw).map(|(a, b)| (a - mf) * (b - mb)).sum::<f64>() / n;
    let sf = (fw.iter().map(|a| (a - mf).powi(2)).sum::<f64>() / n).sqrt();
    let sb = (bw.iter().map(|b| (b - mb).powi(2)).sum::<f64>() / n).sqrt();
    println!("\nper-layer fw/bw time correlation: {:.3} (paper: curves grow at a similar pace)", cov / (sf * sb));
    std::fs::write("fig10.csv", csv).unwrap();
    println!("wrote fig10.csv");
}

//! Multi-tenant planning traffic bench: M worker threads fire a seeded
//! synthetic request stream (mixed graphs × fleets × objectives) at one
//! shared [`ConcurrentService`] and report p50/p99 plan latency, context
//! hit/dedup rates, and throughput scaling against the single-threaded
//! baseline. Feeds BENCH_4.json.
//!
//! `--smoke` runs a seconds-scale configuration for CI: it asserts the
//! structural invariants (every request planned, hits + dedup + misses
//! add up, misses bounded by the distinct-fingerprint count) instead of
//! chasing stable timings on shared runners.

use dnn_partition::coordinator::concurrent::ConcurrentService;
use dnn_partition::coordinator::context::SolveOpts;
use dnn_partition::coordinator::placement::{
    AlgoChoice, DeviceClass, Fleet, Objective, PlanRequest,
};
use dnn_partition::coordinator::planner::Algorithm;
use dnn_partition::graph::OpGraph;
use dnn_partition::util::proptest::random_dag;
use dnn_partition::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// One tenant request: an index into the graph pool plus the plan request.
struct Traffic {
    graph: usize,
    req: PlanRequest,
}

fn fleets() -> Vec<Fleet> {
    vec![
        Fleet::uniform(2, 1, f64::INFINITY),
        Fleet::uniform(4, 1, f64::INFINITY),
        Fleet::new(vec![
            DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
            DeviceClass::acc("slow", 2, f64::INFINITY),
            DeviceClass::cpu("cpu", 1),
        ]),
        Fleet::new(vec![
            DeviceClass::acc("a", 2, 64.0).speed(1.5),
            DeviceClass::acc("b", 2, 32.0),
            DeviceClass::cpu("cpu", 2),
        ]),
    ]
}

/// Seeded request stream: `n` requests drawn from `graphs × fleets ×
/// {objective, contiguity, algorithm}` with repetition by construction —
/// repeats are what exercise the context cache, the single-flight path,
/// and the incumbent cache, exactly like a serving tier re-planning a
/// bounded set of live models.
fn traffic(rng: &mut Rng, n: usize, graphs: usize, fleets: &[Fleet]) -> Vec<Traffic> {
    (0..n)
        .map(|_| {
            let fleet = fleets[rng.gen_range(fleets.len())].clone();
            let mut req = PlanRequest::new(fleet);
            req = match rng.gen_range(4) {
                // IP regimes (warm-seeded): throughput contiguous + not
                0 => req
                    .objective(Objective::Throughput)
                    .algorithm(AlgoChoice::Fixed(Algorithm::IpContiguous)),
                1 => req.objective(Objective::Throughput).contiguous(false),
                // latency IP, both contiguity regimes
                2 => req
                    .objective(Objective::Latency)
                    .contiguous(rng.gen_bool(0.5)),
                // deterministic DP traffic (cache-hit dominated)
                _ => req
                    .objective(Objective::Throughput)
                    .algorithm(AlgoChoice::Fixed(Algorithm::Dp)),
            };
            Traffic { graph: rng.gen_range(graphs), req }
        })
        .collect()
}

/// Drain the stream through the service with `m` workers; returns
/// `(wall time, per-request latencies)`.
fn run(
    svc: &ConcurrentService,
    graphs: &[OpGraph],
    stream: &[Traffic],
    opts: &SolveOpts,
    m: usize,
) -> (Duration, Vec<f64>) {
    let next = AtomicUsize::new(0);
    let started = Instant::now();
    let mut lat_ms: Vec<f64> = Vec::with_capacity(stream.len());
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..m)
            .map(|_| {
                let next = &next;
                scope.spawn(move || {
                    let mut mine = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(t) = stream.get(i) else { break };
                        let t0 = Instant::now();
                        svc.plan_request(&graphs[t.graph], &t.req, opts)
                            .expect("traffic request must plan");
                        mine.push(t0.elapsed().as_secs_f64() * 1e3);
                    }
                    mine
                })
            })
            .collect();
        for h in handles {
            lat_ms.extend(h.join().expect("worker panicked"));
        }
    });
    (started.elapsed(), lat_ms)
}

fn pctl(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[((sorted.len() as f64 - 1.0) * p).round() as usize]
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (n_requests, graph_nodes) = if smoke { (60, 8) } else { (600, 12) };
    let mut rng = Rng::new(0x7AFF1C);
    let graphs: Vec<OpGraph> = (0..3)
        .map(|i| random_dag(&mut rng, graph_nodes + 2 * i, 0.3))
        .collect();
    let fleets = fleets();
    let stream = traffic(&mut rng, n_requests, graphs.len(), &fleets);
    let distinct = {
        use dnn_partition::coordinator::context::fingerprint_req;
        let mut fps: Vec<u64> = stream
            .iter()
            .map(|t| fingerprint_req(&graphs[t.graph], &t.req))
            .collect();
        fps.sort_unstable();
        fps.dedup();
        fps.len()
    };
    let opts = SolveOpts {
        ip_budget: Duration::from_millis(if smoke { 20 } else { 60 }),
        ..SolveOpts::default()
    };
    println!(
        "plan_traffic: {n_requests} requests, {} graphs × {} fleets, {distinct} distinct fingerprints{}",
        graphs.len(),
        fleets.len(),
        if smoke { " (smoke)" } else { "" },
    );

    // single-threaded baseline (fresh service: cold caches)
    let base_svc = ConcurrentService::new(8, 64);
    let (base_wall, mut base_lat) = run(&base_svc, &graphs, &stream, &opts, 1);
    base_lat.sort_by(f64::total_cmp);
    println!(
        "  m=1  wall {:7.1} ms  p50 {:6.2} ms  p99 {:6.2} ms  hits {} misses {} dedup {}",
        base_wall.as_secs_f64() * 1e3,
        pctl(&base_lat, 0.50),
        pctl(&base_lat, 0.99),
        base_svc.hits(),
        base_svc.misses(),
        base_svc.dedup_waits(),
    );

    for m in [2usize, 4, 8] {
        let svc = ConcurrentService::new(8, 64);
        let (wall, mut lat) = run(&svc, &graphs, &stream, &opts, m);
        lat.sort_by(f64::total_cmp);
        let planned = lat.len();
        assert_eq!(planned, n_requests, "every request must be planned exactly once");
        assert_eq!(
            svc.hits() + svc.misses() + svc.dedup_waits(),
            n_requests,
            "every request is a hit, a miss, or a dedup wait"
        );
        assert!(
            svc.misses() <= distinct,
            "single-flight bound: misses ({}) must not exceed distinct fingerprints ({distinct})",
            svc.misses(),
        );
        println!(
            "  m={m}  wall {:7.1} ms  p50 {:6.2} ms  p99 {:6.2} ms  hits {} misses {} dedup {}  scaling {:.2}x",
            wall.as_secs_f64() * 1e3,
            pctl(&lat, 0.50),
            pctl(&lat, 0.99),
            svc.hits(),
            svc.misses(),
            svc.dedup_waits(),
            base_wall.as_secs_f64() / wall.as_secs_f64(),
        );
    }

    // warm-start payoff: re-running the stream against the already-warm
    // baseline service hits both the context cache and the IP incumbents
    let (warm_wall, mut warm_lat) = run(&base_svc, &graphs, &stream, &opts, 4);
    warm_lat.sort_by(f64::total_cmp);
    println!(
        "  warm re-run (m=4): wall {:7.1} ms  p50 {:6.2} ms  p99 {:6.2} ms  ({:.2}x vs cold m=1)",
        warm_wall.as_secs_f64() * 1e3,
        pctl(&warm_lat, 0.50),
        pctl(&warm_lat, 0.99),
        base_wall.as_secs_f64() / warm_wall.as_secs_f64(),
    );
    if smoke {
        println!("plan_traffic smoke OK");
    }
}

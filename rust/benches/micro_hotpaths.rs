//! Criterion-style micro benchmarks of the crate's hot paths (in-tree
//! harness — no criterion offline): ideal enumeration, the DP inner loop,
//! reachability, the simplex, and objective evaluation. These are the
//! §Perf tracking numbers in EXPERIMENTS.md.

use dnn_partition::algos::{dp, objective};
use dnn_partition::coordinator::placement::Scenario;
use dnn_partition::graph::ideals::IdealLattice;
use dnn_partition::graph::topo;
use dnn_partition::solver::lp::{Lp, Sense};
use dnn_partition::util::bench::bench;
use dnn_partition::util::rng::Rng;
use dnn_partition::workloads::{bert, gnmt, resnet};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(
        std::env::var("MICRO_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1500),
    );

    // ideal enumeration on the branchiest real workloads
    let gnmt_g = gnmt::gnmt_layer_graph(false);
    bench("ideals/enumerate/gnmt", budget, 3, || {
        IdealLattice::enumerate(&gnmt_g, usize::MAX).map(|l| l.len()).unwrap_or(0)
    });
    let rn = resnet::resnet50_layer_graph(false);
    bench("ideals/enumerate/resnet50-layer", budget, 3, || {
        IdealLattice::enumerate(&rn, usize::MAX).map(|l| l.len()).unwrap_or(0)
    });

    // full DP solves
    let sc6 = Scenario::new(6, 1, 16.0 * 1024.0);
    bench("dp/solve/resnet50-layer", budget, 3, || dp::solve(&rn, &sc6).map(|p| p.objective));
    let b3 = bert::bert_op_graph(3, false);
    let sc3 = Scenario::new(3, 1, 16.0 * 1024.0);
    bench("dp/solve/bert3-op", budget, 3, || dp::solve(&b3, &sc3).map(|p| p.objective));
    bench("dp/solve/gnmt-layer", budget, 1, || dp::solve(&gnmt_g, &sc6).map(|p| p.objective));

    // reachability / toposort on the biggest op graph
    let b12 = bert::bert_op_graph(12, true);
    bench("graph/reachability/bert12-train", budget, 3, || topo::reachability_matrix(&b12).n());
    bench("graph/toposort/bert12-train", budget, 10, || topo::toposort(&b12).map(|o| o.len()));

    // objective evaluation (the baselines' inner loop)
    let p = dp::solve(&rn, &sc6).unwrap();
    bench("objective/max_load/resnet50", budget, 10, || objective::max_load(&rn, &sc6, &p));
    bench("objective/latency/resnet50", budget, 10, || objective::latency(&rn, &sc6, &p));

    // simplex on a dense random LP (60 vars x 40 rows)
    let mut rng = Rng::new(42);
    let mut lp = Lp::new(60);
    for j in 0..60 {
        lp.objective[j] = rng.gen_f64_range(-1.0, 1.0);
        lp.upper[j] = 10.0;
    }
    for _ in 0..40 {
        let coeffs: Vec<(usize, f64)> =
            (0..60).map(|j| (j, rng.gen_f64_range(0.0, 1.0))).collect();
        lp.add(coeffs, Sense::Le, 50.0);
    }
    bench("solver/simplex/60x40", budget, 5, || lp.solve());
}

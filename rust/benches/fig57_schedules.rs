//! Regenerates **Figures 2, 5, 7**: pipeline schedule timelines and the
//! core cost-model invariant — after ramp-up the steady-state
//! time-per-sample equals the max-load objective, including the
//! non-contiguous virtual-device schedule of Fig. 5b and the 1F1B / GPipe
//! training schedules of Fig. 7.

use dnn_partition::algos::{dp, objective};
use dnn_partition::coordinator::context::SolveOpts;
use dnn_partition::coordinator::placement::{
    AlgoChoice, Device, DeviceClass, Fleet, Placement, PlanRequest, Scenario,
};
use dnn_partition::coordinator::planner::{self, Algorithm};
use dnn_partition::pipeline::sim::{self, Schedule};
use dnn_partition::simx::engine::{self as simx_engine, SimConfig};
use dnn_partition::workloads::bert;
use dnn_partition::graph::{Node, OpGraph};

fn chain(n: usize) -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..n {
        g.add_node(Node::new(format!("op{i}")).cpu(12.0).acc(1.0).mem(1.0).comm(0.1));
    }
    for i in 1..n {
        g.add_edge(i - 1, i);
    }
    g
}

fn main() {
    // --- Fig. 2a/5a: single-stream vs pipelined inference ---
    let g = chain(8);
    let sc = Scenario::new(4, 0, f64::INFINITY);
    let p = dp::solve(&g, &sc).unwrap();
    let predicted = objective::max_load(&g, &sc, &p);
    println!("# Fig. 2a — single-stream model-parallel inference (4 devices, 4 samples)");
    let ss = sim::simulate(&g, &sc, &p, Schedule::SingleStream, 4);
    println!("{}", sim::render_timeline(&ss, 96));
    println!("# Fig. 5a — pipelined inference (same split, 9 samples)");
    let pi = sim::simulate(&g, &sc, &p, Schedule::Pipelined, 9);
    println!("{}", sim::render_timeline(&pi, 96));
    println!(
        "steady-state TPS {:.3} vs max-load {:.3}  (ratio {:.3})\n",
        pi.steady_tps,
        predicted,
        pi.steady_tps / predicted
    );

    // --- Fig. 5b: non-contiguous split on virtual devices ---
    println!("# Fig. 5b — non-contiguous split: device 1 holds {{0,1}} and {{4,5}} (virtual 1a/1b)");
    let g6 = chain(6);
    let sc2 = Scenario::new(2, 0, f64::INFINITY);
    let nc = Placement::new(
        vec![
            Device::Acc(0),
            Device::Acc(0),
            Device::Acc(1),
            Device::Acc(1),
            Device::Acc(0),
            Device::Acc(0),
        ],
        0.0,
        "manual",
    );
    let pred_nc = objective::max_load(&g6, &sc2, &nc);
    let rnc = sim::simulate(&g6, &sc2, &nc, Schedule::Pipelined, 9);
    println!("{}", sim::render_timeline(&rnc, 96));
    println!(
        "virtual devices: {} pieces; steady-state TPS {:.3} vs max-load {:.3} (ratio {:.3})\n",
        rnc.pieces.len(),
        rnc.steady_tps,
        pred_nc,
        rnc.steady_tps / pred_nc
    );

    // --- Fig. 7: training schedules on BERT-24 ---
    println!("# Fig. 7 — pipeline-parallel training schedules (BERT-24, 6 devices, 8 minibatches)");
    let gt = bert::bert24_layer_graph(true);
    let sct = Scenario::new(6, 1, 16.0 * 1024.0);
    let pt = dp::solve(&gt, &sct).unwrap();
    let pred_t = objective::max_load(&gt, &sct, &pt);
    for (sched, name) in [(Schedule::GPipe, "7a GPipe"), (Schedule::PipeDream1F1B, "7b PipeDream 1F1B")] {
        let r = sim::simulate(&gt, &sct, &pt, sched, 8);
        println!("## Fig. {name} (uppercase letters = backward)");
        println!("{}", sim::render_timeline(&r, 96));
        println!("steady-state TPS {:.3} vs objective {:.3}\n", r.steady_tps, pred_t);
    }

    // --- heterogeneous fleet: the same pipeline on mixed device classes ---
    println!(
        "# Heterogeneous fleet — 1 double-speed + 2 baseline accelerators \
         (simx engine, bandwidth-delayed links)"
    );
    let gh = chain(8);
    let req = PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("fast", 1, f64::INFINITY).speed(2.0),
        DeviceClass::acc("slow", 2, f64::INFINITY),
        DeviceClass::cpu("cpu", 1),
    ]))
    .algorithm(AlgoChoice::Fixed(Algorithm::Dp));
    let rp = planner::plan_request(&gh, &req, &SolveOpts::default()).unwrap();
    let pred_h = objective::max_load_req(&gh, &req, &rp.placement);
    let rh = simx_engine::simulate_req(
        &gh,
        &req,
        &rp.placement,
        Schedule::Pipelined,
        12,
        &SimConfig::for_request(&req),
    );
    println!("{}", rh.render_timeline(96));
    println!(
        "steady-state TPS {:.3} vs fleet max-load {:.3}  (ratio {:.3}; {} link transfers)",
        rh.steady_tps,
        pred_h,
        rh.steady_tps / pred_h,
        rh.transfers.len()
    );
}

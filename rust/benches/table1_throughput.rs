//! Regenerates **Table 1** (and the ratio form, **Table 2**): throughput
//! maximization across the 16 pipelined workloads — DP / IP(contiguous) /
//! IP(non-contiguous) / DPL vs Expert / Local search / PipeDream / Scotch.
//!
//! Shape expectations vs the paper (absolute numbers differ — our costs
//! are FLOP-derived, theirs profiled): DP == IP(contig); non-contiguous
//! gain ≥ 0, largest on small-k BERT op graphs; DPL ≈ DP; baselines ≤ DP.
//!
//! Env knobs: `T1_IP_SECS` (per-IP budget, default 5),
//! `T1_IDEAL_CAP` (DP lattice cap, default 20k; graphs whose lattice
//! exceeds it — Inception-v3, like the paper's 36.6k-ideal instance that
//! took the authors' C++ DP 32–58 min — report ">cap" and rely on DPL,
//! which is the paper's own recommendation for such graphs),
//! `T1_FILTER` (substring filter on workload names).

use dnn_partition::algos::{dp, dpl, ip_throughput};
use dnn_partition::baselines::{expert, local_search, pipedream, scotch_like};
use dnn_partition::graph::ideals::IdealLattice;
use dnn_partition::util::bench::{paper_runtime, time_once};
use dnn_partition::workloads::table1_workloads;
use std::time::Duration;

fn main() {
    let ip_secs: u64 =
        std::env::var("T1_IP_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    let cap: usize =
        std::env::var("T1_IDEAL_CAP").ok().and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let filter = std::env::var("T1_FILTER").unwrap_or_default();

    println!("# Table 1 — pipelined throughput (TPS = max-load; lower is better)");
    println!(
        "{:<12} {:>5} {:>8} | {:>7} {:>8} | {:>7} {:>8} | {:>7} {:>8} {:>6} | {:>9} | {:>9} {:>9} {:>9} {:>9}",
        "workload", "nodes", "ideals", "DP-t", "DP", "IPc-t", "IPc", "IPnc-t", "IPnc", "gain",
        "DPL", "Expert", "LocalS", "PipeDr", "Scotch"
    );

    let mut rows: Vec<(String, f64, [f64; 4])> = Vec::new();
    for (i, w) in table1_workloads().into_iter().enumerate() {
        if !filter.is_empty() && !w.name.contains(&filter) {
            continue;
        }
        let section = match i {
            0..=3 => "op/inference",
            4..=7 => "op/training",
            8..=11 => "layer/inference",
            _ => "layer/training",
        };
        let ideals = IdealLattice::count(&w.graph, cap);
        // DP (DPL fallback when the lattice exceeds the cap)
        let (dp_res, dp_t) = time_once(|| dp::solve_with_cap(&w.graph, &w.scenario, cap));
        let (dp_tps, dp_time) = match &dp_res {
            Ok(p) => (p.objective, paper_runtime(dp_t)),
            Err(_) => (f64::NAN, ">cap".into()),
        };
        // DPL
        let (dpl_res, _) = time_once(|| dpl::solve(&w.graph, &w.scenario));
        let dpl_tps = dpl_res.as_ref().map(|p| p.objective).unwrap_or(f64::NAN);
        // IP contiguous / non-contiguous
        let budget = Duration::from_secs(ip_secs);
        let (ipc, _) = time_once(|| {
            ip_throughput::solve(
                &w.graph,
                &w.scenario,
                &ip_throughput::IpOptions { time_limit: budget, ..Default::default() },
            )
        });
        let (ipnc, _) = time_once(|| {
            ip_throughput::solve(
                &w.graph,
                &w.scenario,
                &ip_throughput::IpOptions {
                    contiguous: false,
                    time_limit: budget,
                    ..Default::default()
                },
            )
        });
        let ipc_tps = ipc.as_ref().map(|r| r.placement.objective).unwrap_or(f64::NAN);
        let ipnc_tps = ipnc.as_ref().map(|r| r.placement.objective).unwrap_or(f64::NAN);
        let contig_best = if dp_tps.is_finite() { dp_tps.min(ipc_tps) } else { ipc_tps };
        let gain = (contig_best / ipnc_tps - 1.0) * 100.0;
        // baselines
        let exp = w.expert.map(|style| expert::solve(&w.graph, &w.scenario, style).objective);
        let ls = local_search::solve(&w.graph, &w.scenario, 10, 0xC0FFEE).objective;
        let pd = if w.granularity == dnn_partition::workloads::Granularity::Layer {
            Some(pipedream::solve(&w.graph, &w.scenario).objective)
        } else {
            None
        };
        let sco = scotch_like::solve(&w.graph, &w.scenario, 0x5C07C4).objective;

        println!(
            "{:<12} {:>5} {:>8} | {:>7} {:>8.2} | {:>7} {:>8.2} | {:>7} {:>8.2} {:>5.0}% | {:>9.2} | {:>9} {:>9.2} {:>9} {:>9.2}   [{section}]",
            w.name,
            w.graph.n(),
            ideals,
            dp_time,
            dp_tps,
            ipc.as_ref().map(|r| paper_runtime(r.elapsed)).unwrap_or_default(),
            ipc_tps,
            ipnc.as_ref().map(|r| paper_runtime(r.elapsed)).unwrap_or_default(),
            ipnc_tps,
            gain,
            dpl_tps,
            exp.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            ls,
            pd.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            sco,
        );
        rows.push((
            format!("{} [{}]", w.name, section),
            contig_best,
            [ipnc_tps, exp.unwrap_or(f64::NAN), ls, sco],
        ));
    }

    // Table-2 form: throughput relative to contiguous DP = 1.0×
    println!("\n# Table 2 — throughput improvement relative to DP (contiguous) = 1.00x");
    println!("{:<30} {:>6} {:>8} {:>8} {:>8}", "workload", "IPnc", "Expert", "LocalS", "Scotch");
    for (name, base, vals) in &rows {
        let rel = |v: f64| if v.is_finite() { format!("{:.2}x", base / v) } else { "-".into() };
        println!(
            "{:<30} {:>6} {:>8} {:>8} {:>8}",
            name,
            rel(vals[0]),
            rel(vals[1]),
            rel(vals[2]),
            rel(vals[3])
        );
    }
}

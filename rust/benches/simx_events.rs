//! `simx` engine micro-bench: event throughput (events/sec) and the
//! overhead of fleet-aware simulation (per-class speeds + bandwidth-
//! delayed links) over the uniform-scenario replay. Feeds BENCH_3.json.

use dnn_partition::algos::dp;
use dnn_partition::coordinator::context::SolveOpts;
use dnn_partition::coordinator::placement::{
    AlgoChoice, DeviceClass, Fleet, PlanRequest, Scenario,
};
use dnn_partition::coordinator::planner::{self, Algorithm};
use dnn_partition::graph::{Node, OpGraph};
use dnn_partition::runtime::server::ServingPlanner;
use dnn_partition::simx::controller::{self, ControllerConfig};
use dnn_partition::simx::engine::{self, Schedule, SimConfig};
use dnn_partition::simx::event::EventScript;
use dnn_partition::util::bench::bench;
use std::time::Duration;

fn chain(n: usize) -> OpGraph {
    let mut g = OpGraph::new();
    for i in 0..n {
        g.add_node(Node::new(format!("op{i}")).cpu(12.0).acc(1.0).mem(1.0).comm(0.1));
    }
    for i in 1..n {
        g.add_edge(i - 1, i);
    }
    g
}

fn main() {
    let budget = Duration::from_millis(400);
    let samples = 128;
    let g = chain(12);

    // --- uniform replay (the legacy adapter's configuration) -------------
    let sc = Scenario::new(4, 1, f64::INFINITY);
    let p = dp::solve(&g, &sc).unwrap();
    let uniform_req = sc.to_request();
    let uniform_events = engine::simulate_req(
        &g,
        &uniform_req,
        &p,
        Schedule::Pipelined,
        samples,
        &SimConfig::default(),
    )
    .events_processed;
    let uniform = bench(&format!("simx/uniform-chain12-{samples}samples"), budget, 5, || {
        engine::simulate_req(&g, &uniform_req, &p, Schedule::Pipelined, samples, &SimConfig::default())
    });
    println!(
        "simx/uniform events/sec ≈ {:.0} ({uniform_events} events per run)",
        uniform_events as f64 / uniform.median.as_secs_f64()
    );

    // --- fleet replay: per-class speeds + link transfers ------------------
    let fleet_req = PlanRequest::new(Fleet::new(vec![
        DeviceClass::acc("fast", 2, f64::INFINITY).speed(2.0),
        DeviceClass::acc("slow", 2, f64::INFINITY),
        DeviceClass::cpu("cpu", 1),
    ]))
    .algorithm(AlgoChoice::Fixed(Algorithm::Dp));
    let fp = planner::plan_request(&g, &fleet_req, &SolveOpts::default())
        .unwrap()
        .placement;
    let fleet_cfg = SimConfig::for_request(&fleet_req);
    let fleet_events = engine::simulate_req(
        &g,
        &fleet_req,
        &fp,
        Schedule::Pipelined,
        samples,
        &fleet_cfg,
    )
    .events_processed;
    let fleet = bench(&format!("simx/fleet-chain12-{samples}samples"), budget, 5, || {
        engine::simulate_req(&g, &fleet_req, &fp, Schedule::Pipelined, samples, &fleet_cfg)
    });
    println!(
        "simx/fleet events/sec ≈ {:.0} ({fleet_events} events per run)",
        fleet_events as f64 / fleet.median.as_secs_f64()
    );
    println!(
        "fleet-sim overhead over uniform-sim: {:.2}x (links + per-class resources)",
        fleet.median.as_secs_f64() / uniform.median.as_secs_f64()
    );

    // --- scripted scenario: straggler + spike ----------------------------
    let script = EventScript::parse("slow:acc1*0.5@t=10,spike:+32@t=20").unwrap();
    let scripted = bench(&format!("simx/scripted-chain12-{samples}samples"), budget, 5, || {
        engine::simulate_with_events(
            &g,
            &fleet_req,
            &fp,
            Schedule::Pipelined,
            samples,
            &script,
            &fleet_cfg,
        )
    });
    println!(
        "scripted overhead over plain fleet-sim: {:.2}x",
        scripted.median.as_secs_f64() / fleet.median.as_secs_f64()
    );

    // --- monitored loop: health monitor + hysteresis controller ----------
    // a fail mid-run forces the full detect → probe → decrement-replan
    // path, so this measures the controller's worst common case (epoch
    // replay + re-plan), not just monitor bookkeeping
    let fail_script = EventScript::parse("fail:acc1@t=12").unwrap();
    let monitored = bench(
        &format!("simx/monitored-chain12-{samples}samples"),
        budget,
        5,
        || {
            let mut serving = ServingPlanner::new(Algorithm::Dp, SolveOpts::default());
            controller::run_monitored(
                &g,
                &fleet_req,
                &fail_script,
                Schedule::Pipelined,
                samples,
                &mut serving,
                &ControllerConfig::default(),
            )
            .unwrap()
        },
    );
    println!(
        "monitored fail/replan overhead over plain fleet-sim: {:.2}x",
        monitored.median.as_secs_f64() / fleet.median.as_secs_f64()
    );

    // --- load spike at scale: the dispatcher stress row ------------------
    // 100k in-flight samples make the ready set enormous; the per-device
    // ready queues keep dispatch O(log) per task start where the old flat
    // scan paid O(ready set) — this row is the before/after witness
    let big_samples = 100_000;
    let big_events = engine::simulate_req(
        &g,
        &uniform_req,
        &p,
        Schedule::Pipelined,
        big_samples,
        &SimConfig::default(),
    )
    .events_processed;
    let big = bench(
        &format!("simx/uniform-chain12-{big_samples}samples"),
        Duration::from_secs(5),
        3,
        || {
            engine::simulate_req(
                &g,
                &uniform_req,
                &p,
                Schedule::Pipelined,
                big_samples,
                &SimConfig::default(),
            )
        },
    );
    println!(
        "simx/100k-sample events/sec ≈ {:.0} ({big_events} events per run)",
        big_events as f64 / big.median.as_secs_f64()
    );
}

//! Regenerates **Figure 9**: optimal contiguous vs non-contiguous splits
//! of the BERT-3 operator inference graph onto 3 accelerators + 1 CPU,
//! rendered as Graphviz DOT (colors = devices, red = CPU), plus the
//! throughput gain (paper: 27%).

use dnn_partition::algos::{dp, ip_throughput};
use dnn_partition::coordinator::placement::Scenario;
use dnn_partition::workloads::bert;
use std::time::Duration;

fn main() {
    let g = bert::bert_op_graph(3, false);
    let sc = Scenario::new(3, 1, 16.0 * 1024.0);
    let contig = dp::solve(&g, &sc).expect("DP failed");
    let noncontig = ip_throughput::solve(
        &g,
        &sc,
        &ip_throughput::IpOptions {
            contiguous: false,
            time_limit: Duration::from_secs(
                std::env::var("F9_IP_SECS").ok().and_then(|s| s.parse().ok()).unwrap_or(20),
            ),
            ..Default::default()
        },
    )
    .expect("IP failed");

    // device color mapping: dense index 0..k accs, k = CPU (rotate so CPU
    // renders red = palette[0])
    let k = sc.k;
    let rotate = |dense: Vec<usize>| -> Vec<usize> {
        dense.into_iter().map(|d| if d >= k { 0 } else { d + 1 }).collect()
    };
    std::fs::write("fig9_contiguous.dot", g.to_dot(&rotate(contig.dense(k)), "BERT-3 contiguous"))
        .unwrap();
    std::fs::write(
        "fig9_noncontiguous.dot",
        g.to_dot(&rotate(noncontig.placement.dense(k)), "BERT-3 non-contiguous"),
    )
    .unwrap();
    let gain = (contig.objective / noncontig.placement.objective - 1.0) * 100.0;
    println!(
        "Fig. 9 — BERT-3 op inference on 3 accs + 1 CPU:\n  contiguous TPS {:.2}, non-contiguous TPS {:.2} (gain {:.0}%; paper: 27%)",
        contig.objective, noncontig.placement.objective, gain
    );
    println!("wrote fig9_contiguous.dot / fig9_noncontiguous.dot (render with `dot -Tsvg`)");
}

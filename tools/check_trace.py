#!/usr/bin/env python3
"""Validate a Chrome trace_event JSON file emitted by the CLI.

The CLI's ``--profile FILE`` (wall-clock solver spans + virtual-time simx
lanes) and ``simulate --trace FILE`` (simx lanes only) both write the
Chrome trace_event "JSON Object Format": a top-level object whose
``traceEvents`` array holds ``X`` (complete), ``i`` (instant) and ``M``
(metadata) events. This checker enforces the schema Perfetto / chrome://
tracing actually need, so CI catches a malformed exporter before a human
ever loads a trace.

Usage:
    check_trace.py FILE [--require-solver-spans] [--require-sim-lanes]

Exit status 0 when the file validates (and all required content is
present), 1 with a diagnostic on stderr otherwise. Stdlib only.
"""

import argparse
import json
import sys

NUM = (int, float)


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def is_num(v):
    # bool is an int subclass; a trace with ts=true is malformed
    return isinstance(v, NUM) and not isinstance(v, bool)


def check_event(i, e):
    if not isinstance(e, dict):
        fail(f"traceEvents[{i}] is not an object")
    name = e.get("name")
    if not isinstance(name, str) or not name:
        fail(f"traceEvents[{i}] has no string 'name'")
    ph = e.get("ph")
    if not isinstance(ph, str) or len(ph) != 1:
        fail(f"traceEvents[{i}] ({name!r}) has no one-char 'ph'")
    for key in ("ts", "pid", "tid"):
        if not is_num(e.get(key)):
            fail(f"traceEvents[{i}] ({name!r}) has no numeric {key!r}")
    if ph == "X" and not is_num(e.get("dur")):
        fail(f"traceEvents[{i}] ({name!r}) is 'X' but has no numeric 'dur'")
    if "args" in e and not isinstance(e["args"], dict):
        fail(f"traceEvents[{i}] ({name!r}) has non-object 'args'")
    if "cat" in e and not isinstance(e["cat"], str):
        fail(f"traceEvents[{i}] ({name!r}) has non-string 'cat'")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace_event JSON file to validate")
    ap.add_argument(
        "--require-solver-spans",
        action="store_true",
        help="fail unless at least one 'X' event has cat == 'solver'",
    )
    ap.add_argument(
        "--require-sim-lanes",
        action="store_true",
        help="fail unless at least one event has a cat starting with 'simx.'",
    )
    args = ap.parse_args()

    try:
        with open(args.trace, encoding="utf-8") as f:
            doc = json.load(f)
    except OSError as e:
        fail(f"cannot read {args.trace}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{args.trace} is not valid JSON: {e}")

    if not isinstance(doc, dict):
        fail("top level must be a JSON object (trace_event Object Format)")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("top-level 'traceEvents' must be an array")
    if not events:
        fail("traceEvents is empty")

    for i, e in enumerate(events):
        check_event(i, e)

    spans = sum(1 for e in events if e.get("ph") == "X")
    instants = sum(1 for e in events if e.get("ph") == "i")
    metas = sum(1 for e in events if e.get("ph") == "M")

    if args.require_solver_spans and not any(
        e.get("ph") == "X" and e.get("cat") == "solver" for e in events
    ):
        fail("no 'X' event with cat 'solver' (solver spans missing)")
    if args.require_sim_lanes and not any(
        str(e.get("cat", "")).startswith("simx.") for e in events
    ):
        fail("no event with cat 'simx.*' (simulation lanes missing)")

    print(
        f"check_trace: OK: {args.trace}: {len(events)} events "
        f"({spans} spans, {instants} instants, {metas} metadata)"
    )


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""Proxy benchmark for the arena-lattice refactor (PR 1).

The build container for this repository has no Rust toolchain, so the
before/after numbers in BENCH_1.json are measured with this faithful Python
transliteration of both implementations of the two hot paths the PR
rewrites:

  * ideal enumeration — OLD: dict-of-frozenset interning with a full
    n-node rescan per ideal (mirrors HashMap<BitSet, IdealId> + the
    `for v in 0..n` BFS step + the post-hoc sort);
    NEW: flat int-bitmask arena, hash interning, incremental addable
    frontier, FIFO cardinality order (mirrors SetArena + InternTable).
  * throughput-DP sub-ideal walk — OLD: per-ideal allocations for the
    visited set and per-pair subgraph rescans; NEW: stamped visited array +
    incremental add/remove cost updates (single-thread; the rayon-style
    layer parallelism is Rust-only and comes on top of this).

Both variants are written in the same Python idiom (ints as bitsets, dicts
only where the Rust uses a hash map), so the ratio isolates the algorithmic
change rather than interpreter noise. Absolute times are meaningless;
ratios transfer to the Rust implementation conservatively (Rust amplifies
the allocation/cache effects the arena removes).

Graphs: a three-chain DAG (98 nodes, ~36k ideals — the paper's Table-1
regime, enumeration-only), a GNMT-like encoder/decoder pair with attention
cross edges (96 nodes, 1341 ideals), and an Inception-like chain of
parallel branch blocks (194 ideals).
"""

import time


def gnmt_like():
    """Two parallel chains of 48 with sparse cross edges — the
    encoder/decoder + attention shape (the crosses keep this at 1341
    ideals; see three_chain() for the Table-1-scale case)."""
    n = 96
    preds = [[] for _ in range(n)]
    succs = [[] for _ in range(n)]

    def edge(u, v):
        preds[v].append(u)
        succs[u].append(v)

    half = n // 2
    for i in range(1, half):
        edge(i - 1, i)                  # encoder chain
        edge(half + i - 1, half + i)    # decoder chain
    for i in range(4, half, 6):
        edge(i, half + i)               # attention cross edges
    return preds, succs


def inception_like(blocks=24, width=3):
    """Chain of `blocks` fork/join blocks with `width` parallel branches."""
    preds, succs = [], []

    def add():
        preds.append([])
        succs.append([])
        return len(preds) - 1

    def edge(u, v):
        preds[v].append(u)
        succs[u].append(v)

    prev = add()
    for _ in range(blocks):
        mids = []
        for _ in range(width):
            m = add()
            edge(prev, m)
            mids.append(m)
        j = add()
        for m in mids:
            edge(m, j)
        prev = j
    return preds, succs


# --- OLD enumeration: frozen-set interning, full rescan per ideal ---------

def enumerate_old(preds, succs):
    n = len(preds)
    index = {frozenset(): 0}
    ideals = [frozenset()]
    stack = [0]
    while stack:
        ideal = ideals[stack.pop()]
        for v in range(n):                      # full rescan — O(n) per ideal
            if v in ideal:
                continue
            if all(u in ideal for u in preds[v]):
                bigger = ideal | {v}            # new allocation per step
                if bigger not in index:
                    index[bigger] = len(ideals)
                    ideals.append(bigger)
                    stack.append(index[bigger])
    ideals.sort(key=lambda s: (len(s), hash(s)))  # post-hoc cardinality sort
    return ideals


# --- NEW enumeration: int-bitmask arena + incremental frontier ------------

def enumerate_new(preds, succs):
    pred_mask = [0] * len(preds)
    for v, ps in enumerate(preds):
        for u in ps:
            pred_mask[v] |= 1 << u
    index = {0: 0}
    rows = [0]                                  # flat "arena" of int masks
    frontiers = [sum(1 << v for v, ps in enumerate(preds) if not ps)]
    head = 0
    while head < len(rows):
        ideal, frontier = rows[head], frontiers[head]
        head += 1
        while frontier:
            bit = frontier & -frontier
            frontier ^= bit
            v = bit.bit_length() - 1
            bigger = ideal | bit
            if bigger not in index:
                index[bigger] = len(rows)
                # incremental frontier: parent's minus v, plus newly-enabled
                # successors of v
                fr = frontiers[head - 1] & ~bit
                for w in succs[v]:
                    if pred_mask[w] & ~bigger == 0:
                        fr |= 1 << w
                rows.append(bigger)
                frontiers.append(fr)
    return rows                                  # FIFO order is sorted


# --- DP sub-ideal walk proxies -------------------------------------------

def dp_walk_old(ideals, subs_of):
    """Per-ideal set() allocations + per-pair popcount rescans."""
    total = 0.0
    for i in range(1, len(ideals)):
        visited = {i}                           # fresh allocation per ideal
        stack = [i]
        while stack:
            cur = stack.pop()
            for sub in subs_of[cur]:
                if sub not in visited:
                    visited.add(sub)
                    s = ideals[i] & ~ideals[sub]
                    total += bin(s).count("1")  # rescan of S per pair
                    stack.append(sub)
    return total


def dp_walk_new(ideals, subs_of):
    """Stamped visited array + incremental |S| maintenance with undo."""
    ni = len(ideals)
    visited = [0] * ni
    total = 0.0
    for i in range(1, ni):
        stamp = i
        visited[i] = stamp
        stack = [(i, 0, -1)]
        size = 0                                # |S| maintained incrementally
        subs_cache = subs_of
        while stack:
            cur, cursor, added = stack[-1]
            subs = subs_cache[cur]
            if cursor < len(subs):
                stack[-1] = (cur, cursor + 1, added)
                sub = subs[cursor]
                if visited[sub] == stamp:
                    continue
                visited[sub] = stamp
                size += 1                       # O(1) add
                total += size
                stack.append((sub, 0, sub))
            else:
                stack.pop()
                if added >= 0:
                    size -= 1                   # O(1) undo
    return total


def immediate_subs(rows, succs):
    index = {r: i for i, r in enumerate(rows)}
    subs = [[] for _ in rows]
    for i, r in enumerate(rows):
        m = r
        while m:
            bit = m & -m
            m ^= bit
            v = bit.bit_length() - 1
            if all(not (r >> w) & 1 for w in succs[v]):
                subs[i].append(index[r & ~bit])
    return subs


def three_chain(length=32):
    """Three parallel chains with one late cross edge each — ~35k ideals
    from 98 nodes, the Table-1 'GNMT: 17914 ideals from 96 nodes' regime."""
    preds, succs = [], []

    def add():
        preds.append([])
        succs.append([])
        return len(preds) - 1

    def edge(u, v):
        preds[v].append(u)
        succs[u].append(v)

    chains = []
    for _ in range(3):
        first = add()
        cur = first
        for _ in range(length - 1):
            nxt = add()
            edge(cur, nxt)
            cur = nxt
        chains.append((first, cur))
    sink = add()
    src = add()
    for first, last in chains:
        edge(src, first)
        edge(last, sink)
    return preds, succs


def timeit(f, reps=3):
    best = float("inf")
    out = None
    for _ in range(reps):
        t = time.perf_counter()
        out = f()
        best = min(best, time.perf_counter() - t)
    return best, out


def main():
    results = {}
    # enumeration-only at Table-1 scale (the DP-walk proxy is quadratic in
    # the ideal count, so it runs on the smaller graphs below)
    preds, succs = three_chain()
    t_old, ideals_old = timeit(lambda: enumerate_old(preds, succs), reps=1)
    t_new, rows = timeit(lambda: enumerate_new(preds, succs), reps=1)
    assert len(ideals_old) == len(rows)
    results["three-chain-98"] = {
        "ideals": len(rows),
        "enumerate_old_s": round(t_old, 4),
        "enumerate_new_s": round(t_new, 4),
        "enumerate_speedup": round(t_old / t_new, 2),
    }
    print("three-chain-98", results["three-chain-98"])
    for name, g in [("gnmt-like-96", gnmt_like()), ("inception-like", inception_like())]:
        preds, succs = g
        t_old, ideals_old = timeit(lambda: enumerate_old(preds, succs))
        t_new, rows = timeit(lambda: enumerate_new(preds, succs))
        assert len(ideals_old) == len(rows), (len(ideals_old), len(rows))
        # DP walk on the shared sub-ideal structure
        subs = immediate_subs(rows, succs)
        bit_ideals = rows
        t_dold, a = timeit(lambda: dp_walk_old(bit_ideals, subs), reps=1)
        t_dnew, b = timeit(lambda: dp_walk_new(bit_ideals, subs), reps=1)
        assert a == b, "old and new walks must visit identical (I, S) pairs"
        results[name] = {
            "ideals": len(rows),
            "enumerate_old_s": round(t_old, 4),
            "enumerate_new_s": round(t_new, 4),
            "enumerate_speedup": round(t_old / t_new, 2),
            "dp_walk_old_s": round(t_dold, 4),
            "dp_walk_new_s": round(t_dnew, 4),
            "dp_walk_speedup": round(t_dold / t_dnew, 2),
        }
        print(name, results[name])
    return results


# --- PR-2 proxy: fingerprint-cached planning service ----------------------
#
# The Rust PlannerService keys a ProblemCtx (preprocessing, lattice,
# reachability, and the deterministic DP/DPL solutions) by a content hash
# of (graph, scenario). A cold plan pays analysis + solve; a cache hit pays
# fingerprinting + reuse of the cached solution. The proxy models a plan as
# enumerate_new + immediate_subs + dp_walk_new (analysis + solve) and a hit
# as fingerprint + dict lookup — the same asymmetry the Rust bench
# (benches/repeated_plans.rs) measures natively.

def fingerprint(preds, succs, scenario=(6, 1)):
    h = 0xCBF29CE484222325
    mask = (1 << 64) - 1
    for v, ps in enumerate(preds):
        for u in ps:
            h = ((h ^ (u * 1000003 + v)) * 0x100000001B3) & mask
    for x in scenario:
        h = ((h ^ x) * 0x100000001B3) & mask
    return h


def plan_cold(preds, succs):
    rows = enumerate_new(preds, succs)
    subs = immediate_subs(rows, succs)
    return dp_walk_new(rows, subs)


def cache_proxy(preds, succs, plans=5):
    t_cold, _ = timeit(lambda: [plan_cold(preds, succs) for _ in range(plans)], reps=1)
    cache = {}

    def plan_via_service():
        key = fingerprint(preds, succs)
        if key not in cache:
            cache[key] = plan_cold(preds, succs)
        return cache[key]

    plan_via_service()  # warm the cache (the first, miss-path plan)
    t_hit, _ = timeit(lambda: [plan_via_service() for _ in range(plans)], reps=1)
    return {
        "plans": plans,
        "cold_total_s": round(t_cold, 4),
        "hit_total_s": round(max(t_hit, 1e-6), 6),
        "speedup": round(t_cold / max(t_hit, 1e-6), 1),
    }


def main_pr2():
    results = {}
    # (three_chain is enumeration-scale only: its nested-pair count makes
    # the quadratic dp-walk proxy intractable in Python, as for PR 1)
    for name, g in [
        ("gnmt-like-96", gnmt_like()),
        ("inception-like", inception_like()),
    ]:
        preds, succs = g
        results[name] = cache_proxy(preds, succs)
        print("pr2-cache", name, results[name])
    return results


# --- PR-4 proxy: simx discrete-event engine -------------------------------
#
# The Rust simx engine is a binary-heap event queue (ComputeDone /
# TransferDone / scripted events) over per-device resources plus a ready
# list the dispatcher scans by schedule priority. This proxy transliterates
# that structure (heapq, dict device states, linear ready scan) for a
# pipelined chain of `pieces` stages and `samples` samples, in uniform mode
# (instant hand-offs) and fleet mode (per-class speed lookup + bandwidth-
# delayed link transfer events — roughly doubling the event count), so the
# events/sec figure and the fleet-vs-uniform overhead ratio mirror what
# benches/simx_events.rs measures natively.

import heapq


def simx_proxy(pieces=6, samples=256, fleet=False, bw=1.0, xfer=0.1):
    speeds = [2.0 if fleet and j < pieces // 2 else 1.0 for j in range(pieces)]
    cost = [1.0 + 0.1 * j for j in range(pieces)]
    heap = []  # (t, seq, kind, sample, piece)
    seq = 0
    done = [[False] * pieces for _ in range(samples)]
    arrived = [[j == 0 for j in range(pieces)] for _ in range(samples)]
    busy_until = [0.0] * pieces
    link_free = {}
    ready = [(s, 0) for s in range(samples)]
    events = 0
    heapq.heappush(heap, (0.0, seq, "inject", 0, 0))
    seq += 1
    completed = 0
    while heap:
        t, _, kind, s, j = heapq.heappop(heap)
        events += 1
        if kind == "compute":
            done[s][j] = True
            if j + 1 < pieces:
                if fleet:
                    key = (j, j + 1)
                    start = max(t, link_free.get(key, 0.0))
                    fin = start + xfer / bw
                    link_free[key] = fin
                    heapq.heappush(heap, (fin, seq, "transfer", s, j + 1))
                    seq += 1
                else:
                    arrived[s][j + 1] = True
                    ready.append((s, j + 1))
            else:
                completed += 1
        elif kind == "transfer":
            arrived[s][j] = True
            ready.append((s, j))
        # dispatch: priority = lower sample first (pipelined), device-exclusive
        while True:
            best = None
            for ri, (rs, rj) in enumerate(ready):
                if busy_until[rj] > t or not arrived[rs][rj]:
                    continue
                if best is None or rs < best[0]:
                    best = (rs, rj, ri)
            if best is None:
                break
            rs, rj, ri = best
            ready[ri] = ready[-1]
            ready.pop()
            fin = t + cost[rj] / speeds[rj]
            busy_until[rj] = fin
            heapq.heappush(heap, (fin, seq, "compute", rs, rj))
            seq += 1
    assert completed == samples, (completed, samples)
    return events


def main_pr4():
    results = {}
    for name, fleet in [("uniform", False), ("fleet", True)]:
        t, events = timeit(lambda fleet=fleet: simx_proxy(fleet=fleet))
        results[name] = {
            "events": events,
            "run_s": round(t, 4),
            "events_per_s": round(events / t, 1),
        }
        print("pr4-simx", name, results[name])
    results["fleet_over_uniform_overhead"] = round(
        results["fleet"]["run_s"] / results["uniform"]["run_s"], 2
    )
    print("pr4-simx overhead", results["fleet_over_uniform_overhead"])
    return results


# --- PR-6 proxy: concurrent multi-tenant planning traffic -----------------
#
# The Rust ConcurrentService shards a fingerprint-keyed LRU of Arc'd
# contexts, dedups concurrent same-fingerprint builds (single-flight), and
# warm-starts repeated IP solves from budget-keyed incumbents. Python
# cannot reproduce the thread-level *timing* story (the GIL serializes the
# CPU-bound solve), so this proxy splits the claim into parts that DO
# transfer and parts that are modeled:
#
#   measured — per-request cost of the three configurations, single
#     threaded over a seeded mixed stream (graphs × scenarios × regimes):
#       no-cache        every request pays analysis + solve (plan_cold
#                       + `polish_passes` refine walks, the anytime-IP
#                       polish loop)
#       context-cache   first request per fingerprint pays the miss path;
#                       hits pay fingerprint + lookup + the solve passes
#       cache+warm      hits additionally start from the stored incumbent,
#                       so the polish loop runs 1 pass instead of
#                       `polish_passes` (pass COUNT is the modeled part;
#                       per-pass cost is measured)
#     p50/p99 per-request latency and totals for each.
#   measured — single-flight build counts with REAL threads (lock +
#     condition in-flight table, same protocol as concurrent.rs): builds
#     must equal distinct fingerprints, not requests. Count-based, so the
#     GIL doesn't invalidate it.
#   modeled — M-worker scaling from the measured per-request costs,
#     assuming the solve parallelizes (true in Rust: shard locks are held
#     only for map ops; builds and solves run unlocked). Amdahl-style with
#     the miss path serialized by single-flight.

import threading


PR6_POLISH_PASSES = 3  # cold anytime-IP refine passes; warm-started runs 1


def pr6_stream(seed=0x7AFF1C, n=36):
    """Seeded request stream over 2 graphs × 3 scenarios (6 fingerprints)."""
    graphs = {"gnmt": gnmt_like(), "incep": inception_like()}
    state = seed & ((1 << 64) - 1)
    stream = []
    for _ in range(n):
        # xorshift64 — deterministic across runs, like util::rng::Rng
        state ^= (state << 13) & ((1 << 64) - 1)
        state ^= state >> 7
        state ^= (state << 17) & ((1 << 64) - 1)
        name = "gnmt" if state % 2 == 0 else "incep"
        scenario = (2 + (state >> 8) % 3, 1)  # k ∈ {2,3,4}
        stream.append((name, scenario))
    return graphs, stream


def pr6_traffic_proxy():
    graphs, stream = pr6_stream()

    def analyze(preds, succs):
        rows = enumerate_new(preds, succs)
        return rows, immediate_subs(rows, succs)

    def drain(mode):
        cache = {}  # fingerprint -> analysis artifacts (the ProblemCtx)
        lat = []
        hits = misses = 0
        t_all = time.perf_counter()
        for name, scenario in stream:
            preds, succs = graphs[name]
            t0 = time.perf_counter()
            key = fingerprint(preds, succs, scenario)
            if mode == "no-cache" or key not in cache:
                misses += 1
                rows, subs = analyze(preds, succs)
                cache[key] = (rows, subs)
                passes = PR6_POLISH_PASSES
            else:
                hits += 1
                # hit: analysis artifacts reused from the context cache;
                # a warm start also cuts the polish loop to one pass
                rows, subs = cache[key]
                passes = 1 if mode == "warm" else PR6_POLISH_PASSES
            for _ in range(passes):
                dp_walk_new(rows, subs)
            lat.append((time.perf_counter() - t0) * 1e3)
        wall = time.perf_counter() - t_all
        lat.sort()
        pct = lambda p: lat[round((len(lat) - 1) * p)]
        return {
            "requests": len(stream),
            "hits": hits,
            "misses": misses,
            "wall_s": round(wall, 4),
            "p50_ms": round(pct(0.50), 2),
            "p99_ms": round(pct(0.99), 2),
        }

    out = {}
    for mode in ["no-cache", "ctx-cache", "warm"]:
        out[mode] = drain(mode)
        print("pr6-traffic", mode, out[mode])
    out["warm_over_nocache_speedup"] = round(
        out["no-cache"]["wall_s"] / max(out["warm"]["wall_s"], 1e-9), 2
    )
    print("pr6-traffic warm-over-nocache speedup", out["warm_over_nocache_speedup"])
    return out


def pr6_single_flight_proxy(threads=8):
    """Real-threads single-flight: builds == distinct fingerprints."""
    graphs, stream = pr6_stream(n=24)
    distinct = len({fingerprint(*graphs[n], s) for n, s in stream})
    builds = [0]
    cache = {}
    inflight = {}
    lock = threading.Lock()

    def context(name, scenario):
        preds, succs = graphs[name]
        key = fingerprint(preds, succs, scenario)
        with lock:
            if key in cache:
                return cache[key]
            if key in inflight:
                cv = inflight[key]
                while key not in cache:
                    cv.wait()
                return cache[key]
            cv = threading.Condition(lock)
            inflight[key] = cv
        built = plan_cold(preds, succs)  # build OUTSIDE the lock
        with lock:
            builds[0] += 1
            cache[key] = built
            del inflight[key]
            cv.notify_all()
        return built

    idx = [0]

    def worker():
        while True:
            with lock:
                i = idx[0]
                idx[0] += 1
            if i >= len(stream):
                return
            context(*stream[i])

    ts = [threading.Thread(target=worker) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    out = {
        "threads": threads,
        "requests": len(stream),
        "distinct_fingerprints": distinct,
        "builds": builds[0],
        "single_flight_holds": builds[0] == distinct,
    }
    print("pr6-single-flight", out)
    assert out["single_flight_holds"], out
    return out


def pr6_modeled_scaling(traffic):
    """M-worker wall time from measured per-request costs: hit work
    parallelizes perfectly (shard locks cover map ops only); the miss
    path is serialized per fingerprint by single-flight, so it bounds
    the critical path from below."""
    cold = traffic["no-cache"]
    warm = traffic["warm"]
    miss_cost = cold["wall_s"] / cold["requests"]  # every request = miss path
    total_hit_work = warm["wall_s"] - warm["misses"] * miss_cost
    hit_cost = max(total_hit_work / max(warm["hits"], 1), 1e-9)
    out = {}
    for m in [1, 2, 4, 8]:
        wall = max(
            (warm["misses"] * miss_cost + warm["hits"] * hit_cost) / m,
            miss_cost,  # longest single build bounds the critical path
        )
        out[f"m={m}"] = {
            "modeled_wall_s": round(wall, 4),
            "modeled_scaling_x": round(
                (warm["misses"] * miss_cost + warm["hits"] * hit_cost) / wall, 2
            ),
        }
        print("pr6-scaling", f"m={m}", out[f"m={m}"])
    return out


def main_pr6():
    results = {"traffic": pr6_traffic_proxy()}
    results["single_flight"] = pr6_single_flight_proxy()
    results["modeled_scaling"] = pr6_modeled_scaling(results["traffic"])
    return results


# --- PR-8 proxy: per-device-pair interconnect costs -----------------------
#
# PR 8 threads a device-interconnect Topology (per-ordered-pair
# bandwidth/latency) through the solvers, the objective evaluators and the
# simx engine. The claims that transfer to this Python proxy:
#
#   * uniform identity — a uniform topology's pair-exact evaluation equals
#     the scalar model EXACTLY (slowdown 1.0, latency 0.0 -> `s*1+0 == s`
#     in IEEE-754; the Rust side asserts this bitwise over all 12 registry
#     solvers in tests/topo_equivalence.rs).
#   * pair-aware placements win — on an interleaved 2-island fleet (8x
#     inter/intra gap), a topology-blind optimal chain split replayed on
#     the real interconnect loses to the pair-aware optimum, both in the
#     pair-exact objective and in event-driven simulated time/sample.
#   * bound tightness — the lattice DPs fold comm at the conservative
#     worst-pair bound and re-score exactly; the proxy reports how loose
#     that bound is on the same instance (why expand_req re-scores).


def pr8_topology(n=4, groups=((0, 2), (1, 3)), intra=800.0, inter=100.0):
    """Interleaved islands: devices {0,2} and {1,3}; slowdown matrix
    normalized against the fastest link (min off-diagonal slowdown 1.0),
    exactly like topo::Topology::build."""
    island = {}
    for gi, g in enumerate(groups):
        for m in g:
            island[m] = gi
    ref = max(intra, inter)
    slow = [[1.0] * n for _ in range(n)]
    for a in range(n):
        for b in range(n):
            if a != b:
                bw = intra if island[a] == island[b] else inter
                slow[a][b] = ref / bw
    return slow


def pr8_eval(dev, cost, comm, slow):
    """Pair-exact max-load of a chain placement (objective::max_load_req
    transliterated for a chain: boundary comm charged into the consumer's
    load per pair, and into the producer's at its worst destination —
    one successor on a chain, so the single pair). slow=None is the
    scalar model."""
    load = {}
    for v, d in enumerate(dev):
        load[d] = load.get(d, 0.0) + cost[v]
    for v in range(len(dev) - 1):
        a, b = dev[v], dev[v + 1]
        if a != b:
            t = comm[v] * (1.0 if slow is None else slow[a][b])
            load[b] = load.get(b, 0.0) + t
            load[a] += t
    return max(load.values())


def pr8_solve_chain(cost, comm, k, slow, dense_order_only):
    """Optimal contiguous split of the chain onto <= k devices.
    dense_order_only=True mirrors the topology-blind DP's canonical
    tie-break (segments take devices 0,1,2,... in order); False lets the
    pair-aware solver also permute which device hosts which segment."""
    from itertools import combinations, permutations
    n = len(cost)
    best = (float("inf"), None)
    for segs in range(1, k + 1):
        for cuts in combinations(range(1, n), segs - 1):
            bounds = [0] + list(cuts) + [n]
            orders = (
                [tuple(range(segs))]
                if dense_order_only
                else permutations(range(k), segs)
            )
            for order in orders:
                dev = []
                for si in range(segs):
                    dev += [order[si]] * (bounds[si + 1] - bounds[si])
                obj = pr8_eval(dev, cost, comm, slow)
                if obj < best[0]:
                    best = (obj, dev)
    return best


def pr8_sim(dev, cost, comm, slow, samples=300):
    """Event-driven pipelined replay with exclusive devices and exclusive
    per-directed-pair links at the pair's rate (the simx engine's
    transfer formula: size * slowdown / bw, bw = 1). Returns the
    steady-state time/sample (slope over the back half)."""
    n = len(dev)
    # contract to stages (maximal runs on one device)
    stages = []
    for v in range(n):
        if stages and dev[v] == stages[-1][0]:
            stages[-1][1] += cost[v]
        else:
            stages.append([dev[v], cost[v]])
        stages[-1][2:] = [comm[v]]  # boundary size = last node's comm
    heap, seq = [], 0
    busy = {}
    link_free = {}
    arrived = [[j == 0 for j in range(len(stages))] for _ in range(samples)]
    ready = [(s, 0) for s in range(samples)]
    finish_at = [0.0] * samples
    heapq.heappush(heap, (0.0, seq, "noop", 0, 0))
    seq += 1
    while heap:
        t, _, kind, s, j = heapq.heappop(heap)
        if kind == "compute":
            if j + 1 < len(stages):
                a, b = stages[j][0], stages[j + 1][0]
                start = max(t, link_free.get((a, b), 0.0))
                fin = start + stages[j][2] * slow[a][b]
                link_free[(a, b)] = fin
                heapq.heappush(heap, (fin, seq, "transfer", s, j + 1))
                seq += 1
            else:
                finish_at[s] = t
        elif kind == "transfer":
            arrived[s][j] = True
            ready.append((s, j))
        while True:
            pick = None
            for ri, (rs, rj) in enumerate(ready):
                if busy.get(stages[rj][0], 0.0) > t or not arrived[rs][rj]:
                    continue
                if pick is None or rs < pick[0]:
                    pick = (rs, rj, ri)
            if pick is None:
                break
            rs, rj, ri = pick
            ready[ri] = ready[-1]
            ready.pop()
            fin = t + stages[rj][1]
            busy[stages[rj][0]] = fin
            heapq.heappush(heap, (fin, seq, "compute", rs, rj))
            seq += 1
    half = samples // 2
    return (finish_at[samples - 1] - finish_at[half]) / (samples - 1 - half)


def main_pr8():
    import json
    # The Rust acceptance instance (tests/topo_equivalence.rs): 4-node
    # chain, compute 1.0, boundary comm 0.5, on 4 accelerators in
    # interleaved islands {0,2}/{1,3} at 800/100 — the dense-order split
    # a blind solver emits crosses islands on EVERY boundary.
    cost = [1.0] * 4
    comm = [0.5] * 4
    k = 4
    slow = pr8_topology()
    uniform = [[1.0] * k for _ in range(k)]
    results = {}

    # uniform identity: pair-exact == scalar EXACTLY on every 3-way
    # split of an 8-node chain (and on both solved optima)
    from itertools import combinations
    c8, m8 = [1.0] * 8, [0.5] * 8
    identical = all(
        pr8_eval(d, cost, comm, uniform) == pr8_eval(d, cost, comm, None)
        for _, d in [
            pr8_solve_chain(cost, comm, k, None, True),
            pr8_solve_chain(cost, comm, k, uniform, True),
        ]
    ) and all(
        pr8_eval(d8, c8, m8, [[1.0] * 3 for _ in range(3)])
        == pr8_eval(d8, c8, m8, None)
        for c1, c2 in combinations(range(1, 8), 2)
        for d8 in [[0] * c1 + [1] * (c2 - c1) + [2] * (8 - c2)]
    )
    results["uniform_identity_exact"] = identical
    print("pr8-uniform-identity", identical)
    assert identical

    # topology-blind optimum, re-scored and replayed on the real topology
    blind_obj, blind_dev = pr8_solve_chain(cost, comm, k, None, True)
    blind_rescore = pr8_eval(blind_dev, cost, comm, slow)
    blind_sim = pr8_sim(blind_dev, cost, comm, slow)
    # pair-aware optimum on the same fleet
    aware_obj, aware_dev = pr8_solve_chain(cost, comm, k, slow, False)
    aware_sim = pr8_sim(aware_dev, cost, comm, slow)
    # the lattice DPs' conservative worst-pair fold (before re-scoring)
    wslow = max(slow[a][b] for a in range(k) for b in range(k) if a != b)
    wbound_obj, _ = pr8_solve_chain(cost, [c * wslow for c in comm], k, None, True)

    results["islands_8x_interleaved"] = {
        "blind_model_objective": round(blind_obj, 4),
        "blind_rescored_on_topology": round(blind_rescore, 4),
        "blind_sim_time_per_sample": round(blind_sim, 4),
        "aware_objective": round(aware_obj, 4),
        "aware_sim_time_per_sample": round(aware_sim, 4),
        "aware_over_blind_sim_speedup_x": round(blind_sim / aware_sim, 2),
        "worst_pair_bound_objective": round(wbound_obj, 4),
        "bound_over_exact_x": round(wbound_obj / aware_obj, 2),
    }
    print("pr8-islands", results["islands_8x_interleaved"])
    assert aware_sim < blind_sim, (aware_sim, blind_sim)
    assert aware_obj < blind_rescore, (aware_obj, blind_rescore)

    # Table-1 shape: a BERT-12-like layer-granularity chain (12 uniform
    # transformer layers, heavy boundary activations) on the same
    # interleaved 2-island fleet at the CI smoke's 900/64 rates (14x).
    # The blind 4-way split puts every boundary on an inter-island link;
    # the pair-aware optimum retreats to one island and wins in both the
    # model and the event replay.
    b_cost = [1.0] * 12
    b_comm = [0.5] * 12
    b_slow = pr8_topology(intra=900.0, inter=64.0)
    bb_obj, bb_dev = pr8_solve_chain(b_cost, b_comm, k, None, True)
    bb_rescore = pr8_eval(bb_dev, b_cost, b_comm, b_slow)
    bb_sim = pr8_sim(bb_dev, b_cost, b_comm, b_slow)
    ba_obj, ba_dev = pr8_solve_chain(b_cost, b_comm, k, b_slow, False)
    ba_sim = pr8_sim(ba_dev, b_cost, b_comm, b_slow)
    results["bert12_like_chain_islands_14x"] = {
        "blind_model_objective": round(bb_obj, 4),
        "blind_rescored_on_topology": round(bb_rescore, 4),
        "blind_sim_time_per_sample": round(bb_sim, 4),
        "aware_objective": round(ba_obj, 4),
        "aware_sim_time_per_sample": round(ba_sim, 4),
        "aware_over_blind_sim_speedup_x": round(bb_sim / ba_sim, 2),
        "island_vs_uniform_objective_gap_x": round(bb_rescore / ba_obj, 2),
    }
    print("pr8-bert12-like", results["bert12_like_chain_islands_14x"])
    assert ba_sim < bb_sim, (ba_sim, bb_sim)
    assert ba_obj < bb_rescore, (ba_obj, bb_rescore)

    bench = {
        "pr": 8,
        "title": "Hierarchical device-interconnect topology: per-device-pair "
        "comm costs through solvers, objectives, simx, and the serving loop",
        "date": "2026-08-08",
        "methodology": {
            "note": "This PR's build container has no Rust toolchain (no "
            "cargo/rustc on the image), so the native acceptance numbers "
            "(tests/topo_equivalence.rs) could not be executed here; the "
            "figures below are from this Python transliteration of the "
            "pair-exact cost model (objective::max_load_req on a chain), "
            "the solvers' split search, and the simx per-pair link replay. "
            "Instance: 4-node chain (compute 1.0, boundary comm 0.5) on 4 "
            "accelerators in interleaved islands {0,2}/{1,3} at 800 intra "
            "/ 100 inter (8x gap) -- the same shape the Rust acceptance "
            "test pins -- plus a Table-1-shaped BERT-12-like 12-layer "
            "chain on the same interleaved islands at the CI smoke's "
            "900/64 rates (14x gap). MEASURED: (a) uniform-topology "
            "evaluation is "
            "EXACTLY equal (Python float ==, mirroring the Rust bitwise "
            "assertion) to the scalar model on every contiguous split; "
            "(b) the topology-blind optimal split (canonical dense device "
            "order, all three boundaries forced onto 8x-slow inter-island "
            "links) re-scored and event-replayed on the real topology vs "
            "the pair-aware optimum, which groups stages within islands; "
            "(c) the lattice DPs' conservative worst-pair fold on the "
            "same instance, showing why Prepared::expand_req re-scores "
            "candidates pair-exactly. Rerun natively when a toolchain is "
            "available: cargo test --test topo_equivalence, and the CI "
            "cross-island smoke (partition + simulate on "
            "topo=islands:2x4@900/64).",
            "command": "python3 tools/bench_proxy.py --pr8",
            "rust_benches_to_rerun_when_toolchain_available": [
                "cargo test --test topo_equivalence",
                "cargo run --release -- partition bert24 ip --fleet "
                "'8xacc:32768,1xcpu,topo=islands:2x4@900/64' 5",
                "cargo run --release -- simulate bert24 dp 24 --fleet "
                "'8xacc:32768,1xcpu,topo=islands:2x4@900/64'",
            ],
        },
        "results": results,
    }
    with open("BENCH_5.json", "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    print("wrote BENCH_5.json")
    return results


if __name__ == "__main__":
    import sys
    if "--pr2" in sys.argv:
        main_pr2()
    elif "--pr4" in sys.argv:
        main_pr4()
    elif "--pr6" in sys.argv:
        main_pr6()
    elif "--pr8" in sys.argv:
        main_pr8()
    else:
        main()
        main_pr2()
        main_pr4()
        main_pr6()
        main_pr8()

#!/usr/bin/env python3
"""Proxy benchmark for the arena-lattice refactor (PR 1).

The build container for this repository has no Rust toolchain, so the
before/after numbers in BENCH_1.json are measured with this faithful Python
transliteration of both implementations of the two hot paths the PR
rewrites:

  * ideal enumeration — OLD: dict-of-frozenset interning with a full
    n-node rescan per ideal (mirrors HashMap<BitSet, IdealId> + the
    `for v in 0..n` BFS step + the post-hoc sort);
    NEW: flat int-bitmask arena, hash interning, incremental addable
    frontier, FIFO cardinality order (mirrors SetArena + InternTable).
  * throughput-DP sub-ideal walk — OLD: per-ideal allocations for the
    visited set and per-pair subgraph rescans; NEW: stamped visited array +
    incremental add/remove cost updates (single-thread; the rayon-style
    layer parallelism is Rust-only and comes on top of this).

Both variants are written in the same Python idiom (ints as bitsets, dicts
only where the Rust uses a hash map), so the ratio isolates the algorithmic
change rather than interpreter noise. Absolute times are meaningless;
ratios transfer to the Rust implementation conservatively (Rust amplifies
the allocation/cache effects the arena removes).

Graphs: a three-chain DAG (98 nodes, ~36k ideals — the paper's Table-1
regime, enumeration-only), a GNMT-like encoder/decoder pair with attention
cross edges (96 nodes, 1341 ideals), and an Inception-like chain of
parallel branch blocks (194 ideals).
"""

import time


def gnmt_like():
    """Two parallel chains of 48 with sparse cross edges — the
    encoder/decoder + attention shape (the crosses keep this at 1341
    ideals; see three_chain() for the Table-1-scale case)."""
    n = 96
    preds = [[] for _ in range(n)]
    succs = [[] for _ in range(n)]

    def edge(u, v):
        preds[v].append(u)
        succs[u].append(v)

    half = n // 2
    for i in range(1, half):
        edge(i - 1, i)                  # encoder chain
        edge(half + i - 1, half + i)    # decoder chain
    for i in range(4, half, 6):
        edge(i, half + i)               # attention cross edges
    return preds, succs


def inception_like(blocks=24, width=3):
    """Chain of `blocks` fork/join blocks with `width` parallel branches."""
    preds, succs = [], []

    def add():
        preds.append([])
        succs.append([])
        return len(preds) - 1

    def edge(u, v):
        preds[v].append(u)
        succs[u].append(v)

    prev = add()
    for _ in range(blocks):
        mids = []
        for _ in range(width):
            m = add()
            edge(prev, m)
            mids.append(m)
        j = add()
        for m in mids:
            edge(m, j)
        prev = j
    return preds, succs


# --- OLD enumeration: frozen-set interning, full rescan per ideal ---------

def enumerate_old(preds, succs):
    n = len(preds)
    index = {frozenset(): 0}
    ideals = [frozenset()]
    stack = [0]
    while stack:
        ideal = ideals[stack.pop()]
        for v in range(n):                      # full rescan — O(n) per ideal
            if v in ideal:
                continue
            if all(u in ideal for u in preds[v]):
                bigger = ideal | {v}            # new allocation per step
                if bigger not in index:
                    index[bigger] = len(ideals)
                    ideals.append(bigger)
                    stack.append(index[bigger])
    ideals.sort(key=lambda s: (len(s), hash(s)))  # post-hoc cardinality sort
    return ideals


# --- NEW enumeration: int-bitmask arena + incremental frontier ------------

def enumerate_new(preds, succs):
    pred_mask = [0] * len(preds)
    for v, ps in enumerate(preds):
        for u in ps:
            pred_mask[v] |= 1 << u
    index = {0: 0}
    rows = [0]                                  # flat "arena" of int masks
    frontiers = [sum(1 << v for v, ps in enumerate(preds) if not ps)]
    head = 0
    while head < len(rows):
        ideal, frontier = rows[head], frontiers[head]
        head += 1
        while frontier:
            bit = frontier & -frontier
            frontier ^= bit
            v = bit.bit_length() - 1
            bigger = ideal | bit
            if bigger not in index:
                index[bigger] = len(rows)
                # incremental frontier: parent's minus v, plus newly-enabled
                # successors of v
                fr = frontiers[head - 1] & ~bit
                for w in succs[v]:
                    if pred_mask[w] & ~bigger == 0:
                        fr |= 1 << w
                rows.append(bigger)
                frontiers.append(fr)
    return rows                                  # FIFO order is sorted


# --- DP sub-ideal walk proxies -------------------------------------------

def dp_walk_old(ideals, subs_of):
    """Per-ideal set() allocations + per-pair popcount rescans."""
    total = 0.0
    for i in range(1, len(ideals)):
        visited = {i}                           # fresh allocation per ideal
        stack = [i]
        while stack:
            cur = stack.pop()
            for sub in subs_of[cur]:
                if sub not in visited:
                    visited.add(sub)
                    s = ideals[i] & ~ideals[sub]
                    total += bin(s).count("1")  # rescan of S per pair
                    stack.append(sub)
    return total


def dp_walk_new(ideals, subs_of):
    """Stamped visited array + incremental |S| maintenance with undo."""
    ni = len(ideals)
    visited = [0] * ni
    total = 0.0
    for i in range(1, ni):
        stamp = i
        visited[i] = stamp
        stack = [(i, 0, -1)]
        size = 0                                # |S| maintained incrementally
        subs_cache = subs_of
        while stack:
            cur, cursor, added = stack[-1]
            subs = subs_cache[cur]
            if cursor < len(subs):
                stack[-1] = (cur, cursor + 1, added)
                sub = subs[cursor]
                if visited[sub] == stamp:
                    continue
                visited[sub] = stamp
                size += 1                       # O(1) add
                total += size
                stack.append((sub, 0, sub))
            else:
                stack.pop()
                if added >= 0:
                    size -= 1                   # O(1) undo
    return total


def immediate_subs(rows, succs):
    index = {r: i for i, r in enumerate(rows)}
    subs = [[] for _ in rows]
    for i, r in enumerate(rows):
        m = r
        while m:
            bit = m & -m
            m ^= bit
            v = bit.bit_length() - 1
            if all(not (r >> w) & 1 for w in succs[v]):
                subs[i].append(index[r & ~bit])
    return subs


def three_chain(length=32):
    """Three parallel chains with one late cross edge each — ~35k ideals
    from 98 nodes, the Table-1 'GNMT: 17914 ideals from 96 nodes' regime."""
    preds, succs = [], []

    def add():
        preds.append([])
        succs.append([])
        return len(preds) - 1

    def edge(u, v):
        preds[v].append(u)
        succs[u].append(v)

    chains = []
    for _ in range(3):
        first = add()
        cur = first
        for _ in range(length - 1):
            nxt = add()
            edge(cur, nxt)
            cur = nxt
        chains.append((first, cur))
    sink = add()
    src = add()
    for first, last in chains:
        edge(src, first)
        edge(last, sink)
    return preds, succs


def timeit(f, reps=3):
    best = float("inf")
    out = None
    for _ in range(reps):
        t = time.perf_counter()
        out = f()
        best = min(best, time.perf_counter() - t)
    return best, out


def main():
    results = {}
    # enumeration-only at Table-1 scale (the DP-walk proxy is quadratic in
    # the ideal count, so it runs on the smaller graphs below)
    preds, succs = three_chain()
    t_old, ideals_old = timeit(lambda: enumerate_old(preds, succs), reps=1)
    t_new, rows = timeit(lambda: enumerate_new(preds, succs), reps=1)
    assert len(ideals_old) == len(rows)
    results["three-chain-98"] = {
        "ideals": len(rows),
        "enumerate_old_s": round(t_old, 4),
        "enumerate_new_s": round(t_new, 4),
        "enumerate_speedup": round(t_old / t_new, 2),
    }
    print("three-chain-98", results["three-chain-98"])
    for name, g in [("gnmt-like-96", gnmt_like()), ("inception-like", inception_like())]:
        preds, succs = g
        t_old, ideals_old = timeit(lambda: enumerate_old(preds, succs))
        t_new, rows = timeit(lambda: enumerate_new(preds, succs))
        assert len(ideals_old) == len(rows), (len(ideals_old), len(rows))
        # DP walk on the shared sub-ideal structure
        subs = immediate_subs(rows, succs)
        bit_ideals = rows
        t_dold, a = timeit(lambda: dp_walk_old(bit_ideals, subs), reps=1)
        t_dnew, b = timeit(lambda: dp_walk_new(bit_ideals, subs), reps=1)
        assert a == b, "old and new walks must visit identical (I, S) pairs"
        results[name] = {
            "ideals": len(rows),
            "enumerate_old_s": round(t_old, 4),
            "enumerate_new_s": round(t_new, 4),
            "enumerate_speedup": round(t_old / t_new, 2),
            "dp_walk_old_s": round(t_dold, 4),
            "dp_walk_new_s": round(t_dnew, 4),
            "dp_walk_speedup": round(t_dold / t_dnew, 2),
        }
        print(name, results[name])
    return results


# --- PR-2 proxy: fingerprint-cached planning service ----------------------
#
# The Rust PlannerService keys a ProblemCtx (preprocessing, lattice,
# reachability, and the deterministic DP/DPL solutions) by a content hash
# of (graph, scenario). A cold plan pays analysis + solve; a cache hit pays
# fingerprinting + reuse of the cached solution. The proxy models a plan as
# enumerate_new + immediate_subs + dp_walk_new (analysis + solve) and a hit
# as fingerprint + dict lookup — the same asymmetry the Rust bench
# (benches/repeated_plans.rs) measures natively.

def fingerprint(preds, succs, scenario=(6, 1)):
    h = 0xCBF29CE484222325
    mask = (1 << 64) - 1
    for v, ps in enumerate(preds):
        for u in ps:
            h = ((h ^ (u * 1000003 + v)) * 0x100000001B3) & mask
    for x in scenario:
        h = ((h ^ x) * 0x100000001B3) & mask
    return h


def plan_cold(preds, succs):
    rows = enumerate_new(preds, succs)
    subs = immediate_subs(rows, succs)
    return dp_walk_new(rows, subs)


def cache_proxy(preds, succs, plans=5):
    t_cold, _ = timeit(lambda: [plan_cold(preds, succs) for _ in range(plans)], reps=1)
    cache = {}

    def plan_via_service():
        key = fingerprint(preds, succs)
        if key not in cache:
            cache[key] = plan_cold(preds, succs)
        return cache[key]

    plan_via_service()  # warm the cache (the first, miss-path plan)
    t_hit, _ = timeit(lambda: [plan_via_service() for _ in range(plans)], reps=1)
    return {
        "plans": plans,
        "cold_total_s": round(t_cold, 4),
        "hit_total_s": round(max(t_hit, 1e-6), 6),
        "speedup": round(t_cold / max(t_hit, 1e-6), 1),
    }


def main_pr2():
    results = {}
    # (three_chain is enumeration-scale only: its nested-pair count makes
    # the quadratic dp-walk proxy intractable in Python, as for PR 1)
    for name, g in [
        ("gnmt-like-96", gnmt_like()),
        ("inception-like", inception_like()),
    ]:
        preds, succs = g
        results[name] = cache_proxy(preds, succs)
        print("pr2-cache", name, results[name])
    return results


# --- PR-4 proxy: simx discrete-event engine -------------------------------
#
# The Rust simx engine is a binary-heap event queue (ComputeDone /
# TransferDone / scripted events) over per-device resources plus a ready
# list the dispatcher scans by schedule priority. This proxy transliterates
# that structure (heapq, dict device states, linear ready scan) for a
# pipelined chain of `pieces` stages and `samples` samples, in uniform mode
# (instant hand-offs) and fleet mode (per-class speed lookup + bandwidth-
# delayed link transfer events — roughly doubling the event count), so the
# events/sec figure and the fleet-vs-uniform overhead ratio mirror what
# benches/simx_events.rs measures natively.

import heapq


def simx_proxy(pieces=6, samples=256, fleet=False, bw=1.0, xfer=0.1):
    speeds = [2.0 if fleet and j < pieces // 2 else 1.0 for j in range(pieces)]
    cost = [1.0 + 0.1 * j for j in range(pieces)]
    heap = []  # (t, seq, kind, sample, piece)
    seq = 0
    done = [[False] * pieces for _ in range(samples)]
    arrived = [[j == 0 for j in range(pieces)] for _ in range(samples)]
    busy_until = [0.0] * pieces
    link_free = {}
    ready = [(s, 0) for s in range(samples)]
    events = 0
    heapq.heappush(heap, (0.0, seq, "inject", 0, 0))
    seq += 1
    completed = 0
    while heap:
        t, _, kind, s, j = heapq.heappop(heap)
        events += 1
        if kind == "compute":
            done[s][j] = True
            if j + 1 < pieces:
                if fleet:
                    key = (j, j + 1)
                    start = max(t, link_free.get(key, 0.0))
                    fin = start + xfer / bw
                    link_free[key] = fin
                    heapq.heappush(heap, (fin, seq, "transfer", s, j + 1))
                    seq += 1
                else:
                    arrived[s][j + 1] = True
                    ready.append((s, j + 1))
            else:
                completed += 1
        elif kind == "transfer":
            arrived[s][j] = True
            ready.append((s, j))
        # dispatch: priority = lower sample first (pipelined), device-exclusive
        while True:
            best = None
            for ri, (rs, rj) in enumerate(ready):
                if busy_until[rj] > t or not arrived[rs][rj]:
                    continue
                if best is None or rs < best[0]:
                    best = (rs, rj, ri)
            if best is None:
                break
            rs, rj, ri = best
            ready[ri] = ready[-1]
            ready.pop()
            fin = t + cost[rj] / speeds[rj]
            busy_until[rj] = fin
            heapq.heappush(heap, (fin, seq, "compute", rs, rj))
            seq += 1
    assert completed == samples, (completed, samples)
    return events


def main_pr4():
    results = {}
    for name, fleet in [("uniform", False), ("fleet", True)]:
        t, events = timeit(lambda fleet=fleet: simx_proxy(fleet=fleet))
        results[name] = {
            "events": events,
            "run_s": round(t, 4),
            "events_per_s": round(events / t, 1),
        }
        print("pr4-simx", name, results[name])
    results["fleet_over_uniform_overhead"] = round(
        results["fleet"]["run_s"] / results["uniform"]["run_s"], 2
    )
    print("pr4-simx overhead", results["fleet_over_uniform_overhead"])
    return results


# --- PR-6 proxy: concurrent multi-tenant planning traffic -----------------
#
# The Rust ConcurrentService shards a fingerprint-keyed LRU of Arc'd
# contexts, dedups concurrent same-fingerprint builds (single-flight), and
# warm-starts repeated IP solves from budget-keyed incumbents. Python
# cannot reproduce the thread-level *timing* story (the GIL serializes the
# CPU-bound solve), so this proxy splits the claim into parts that DO
# transfer and parts that are modeled:
#
#   measured — per-request cost of the three configurations, single
#     threaded over a seeded mixed stream (graphs × scenarios × regimes):
#       no-cache        every request pays analysis + solve (plan_cold
#                       + `polish_passes` refine walks, the anytime-IP
#                       polish loop)
#       context-cache   first request per fingerprint pays the miss path;
#                       hits pay fingerprint + lookup + the solve passes
#       cache+warm      hits additionally start from the stored incumbent,
#                       so the polish loop runs 1 pass instead of
#                       `polish_passes` (pass COUNT is the modeled part;
#                       per-pass cost is measured)
#     p50/p99 per-request latency and totals for each.
#   measured — single-flight build counts with REAL threads (lock +
#     condition in-flight table, same protocol as concurrent.rs): builds
#     must equal distinct fingerprints, not requests. Count-based, so the
#     GIL doesn't invalidate it.
#   modeled — M-worker scaling from the measured per-request costs,
#     assuming the solve parallelizes (true in Rust: shard locks are held
#     only for map ops; builds and solves run unlocked). Amdahl-style with
#     the miss path serialized by single-flight.

import threading


PR6_POLISH_PASSES = 3  # cold anytime-IP refine passes; warm-started runs 1


def pr6_stream(seed=0x7AFF1C, n=36):
    """Seeded request stream over 2 graphs × 3 scenarios (6 fingerprints)."""
    graphs = {"gnmt": gnmt_like(), "incep": inception_like()}
    state = seed & ((1 << 64) - 1)
    stream = []
    for _ in range(n):
        # xorshift64 — deterministic across runs, like util::rng::Rng
        state ^= (state << 13) & ((1 << 64) - 1)
        state ^= state >> 7
        state ^= (state << 17) & ((1 << 64) - 1)
        name = "gnmt" if state % 2 == 0 else "incep"
        scenario = (2 + (state >> 8) % 3, 1)  # k ∈ {2,3,4}
        stream.append((name, scenario))
    return graphs, stream


def pr6_traffic_proxy():
    graphs, stream = pr6_stream()

    def analyze(preds, succs):
        rows = enumerate_new(preds, succs)
        return rows, immediate_subs(rows, succs)

    def drain(mode):
        cache = {}  # fingerprint -> analysis artifacts (the ProblemCtx)
        lat = []
        hits = misses = 0
        t_all = time.perf_counter()
        for name, scenario in stream:
            preds, succs = graphs[name]
            t0 = time.perf_counter()
            key = fingerprint(preds, succs, scenario)
            if mode == "no-cache" or key not in cache:
                misses += 1
                rows, subs = analyze(preds, succs)
                cache[key] = (rows, subs)
                passes = PR6_POLISH_PASSES
            else:
                hits += 1
                # hit: analysis artifacts reused from the context cache;
                # a warm start also cuts the polish loop to one pass
                rows, subs = cache[key]
                passes = 1 if mode == "warm" else PR6_POLISH_PASSES
            for _ in range(passes):
                dp_walk_new(rows, subs)
            lat.append((time.perf_counter() - t0) * 1e3)
        wall = time.perf_counter() - t_all
        lat.sort()
        pct = lambda p: lat[round((len(lat) - 1) * p)]
        return {
            "requests": len(stream),
            "hits": hits,
            "misses": misses,
            "wall_s": round(wall, 4),
            "p50_ms": round(pct(0.50), 2),
            "p99_ms": round(pct(0.99), 2),
        }

    out = {}
    for mode in ["no-cache", "ctx-cache", "warm"]:
        out[mode] = drain(mode)
        print("pr6-traffic", mode, out[mode])
    out["warm_over_nocache_speedup"] = round(
        out["no-cache"]["wall_s"] / max(out["warm"]["wall_s"], 1e-9), 2
    )
    print("pr6-traffic warm-over-nocache speedup", out["warm_over_nocache_speedup"])
    return out


def pr6_single_flight_proxy(threads=8):
    """Real-threads single-flight: builds == distinct fingerprints."""
    graphs, stream = pr6_stream(n=24)
    distinct = len({fingerprint(*graphs[n], s) for n, s in stream})
    builds = [0]
    cache = {}
    inflight = {}
    lock = threading.Lock()

    def context(name, scenario):
        preds, succs = graphs[name]
        key = fingerprint(preds, succs, scenario)
        with lock:
            if key in cache:
                return cache[key]
            if key in inflight:
                cv = inflight[key]
                while key not in cache:
                    cv.wait()
                return cache[key]
            cv = threading.Condition(lock)
            inflight[key] = cv
        built = plan_cold(preds, succs)  # build OUTSIDE the lock
        with lock:
            builds[0] += 1
            cache[key] = built
            del inflight[key]
            cv.notify_all()
        return built

    idx = [0]

    def worker():
        while True:
            with lock:
                i = idx[0]
                idx[0] += 1
            if i >= len(stream):
                return
            context(*stream[i])

    ts = [threading.Thread(target=worker) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    out = {
        "threads": threads,
        "requests": len(stream),
        "distinct_fingerprints": distinct,
        "builds": builds[0],
        "single_flight_holds": builds[0] == distinct,
    }
    print("pr6-single-flight", out)
    assert out["single_flight_holds"], out
    return out


def pr6_modeled_scaling(traffic):
    """M-worker wall time from measured per-request costs: hit work
    parallelizes perfectly (shard locks cover map ops only); the miss
    path is serialized per fingerprint by single-flight, so it bounds
    the critical path from below."""
    cold = traffic["no-cache"]
    warm = traffic["warm"]
    miss_cost = cold["wall_s"] / cold["requests"]  # every request = miss path
    total_hit_work = warm["wall_s"] - warm["misses"] * miss_cost
    hit_cost = max(total_hit_work / max(warm["hits"], 1), 1e-9)
    out = {}
    for m in [1, 2, 4, 8]:
        wall = max(
            (warm["misses"] * miss_cost + warm["hits"] * hit_cost) / m,
            miss_cost,  # longest single build bounds the critical path
        )
        out[f"m={m}"] = {
            "modeled_wall_s": round(wall, 4),
            "modeled_scaling_x": round(
                (warm["misses"] * miss_cost + warm["hits"] * hit_cost) / wall, 2
            ),
        }
        print("pr6-scaling", f"m={m}", out[f"m={m}"])
    return out


def main_pr6():
    results = {"traffic": pr6_traffic_proxy()}
    results["single_flight"] = pr6_single_flight_proxy()
    results["modeled_scaling"] = pr6_modeled_scaling(results["traffic"])
    return results


if __name__ == "__main__":
    import sys
    if "--pr2" in sys.argv:
        main_pr2()
    elif "--pr4" in sys.argv:
        main_pr4()
    elif "--pr6" in sys.argv:
        main_pr6()
    else:
        main()
        main_pr2()
        main_pr4()
        main_pr6()
